//! Multi-replica router integration: the cross-replica determinism
//! matrix, failover/poisoning, prefix-affinity soak, and backpressure
//! shedding.
//!
//! The headline contract mirrors `tests/tp.rs` for tensor parallelism:
//! the replica count is a *capacity* knob, never part of the reproducible
//! configuration. The same deterministic workload submitted in the same
//! order produces bitwise-identical committed streams, per-stream
//! digests, and router fleet digests at 1, 2, and 4 replicas — across
//! scheduler policies, prefix-cache settings, verify policies, and under
//! forced-mismatch rollbacks. Failures are contained per replica: a
//! poisoned replica drains from rotation while the survivors' streams
//! stay bitwise unchanged, and only an all-dead fleet reports poisoned.

use std::sync::mpsc::{self, Receiver};
use std::sync::Arc;

use llm42::engine::{
    EngineConfig, FaultPlan, Mode, PolicyKind, Request, VerifyPolicy,
    VerifyPolicyKind,
};
use llm42::obs::DIGEST_EMPTY;
use llm42::prelude::*;
use llm42::tokenizer::{Tokenizer, FIRST_MERGE};
use llm42::util::json::Json;

fn artifacts_dir() -> String {
    let dir = std::env::var("LLM42_ARTIFACTS").unwrap_or_else(|_| "artifacts".into());
    llm42::aot::ensure(&dir).expect("artifact generation failed");
    dir
}

fn tok() -> Arc<Tokenizer> {
    Arc::new(Tokenizer::default_trained(FIRST_MERGE as usize + 64).unwrap())
}

fn base_cfg() -> EngineConfig {
    EngineConfig {
        mode: Mode::Llm42,
        verify_group: 2,
        verify_window: 16,
        max_stall_steps: 4,
        ..Default::default()
    }
}

/// Deterministic-only workload with a shared 32-token prefix (two full KV
/// blocks, so prefix affinity and the prefix cache both engage) plus one
/// unrelated prompt. All-deterministic matters: the fleet digest folds
/// only deterministic streams, and only those are guaranteed identical
/// across replica counts (nondet streams are batch-composition-dependent
/// by design).
fn det_workload() -> Vec<Request> {
    let shared: Vec<u32> = (100..132).collect();
    let mk = |extra: u32, n: usize, seed: u64| {
        let mut prompt = shared.clone();
        prompt.extend(extra..extra + 4);
        Request {
            prompt,
            max_new_tokens: n,
            deterministic: true,
            temperature: 1.0,
            seed,
            ..Default::default()
        }
    };
    vec![
        mk(200, 20, 11),
        mk(210, 16, 12),
        mk(220, 12, 13),
        Request {
            prompt: (10..22).collect(),
            max_new_tokens: 18,
            deterministic: true,
            temperature: 0.0,
            seed: 0,
            ..Default::default()
        },
    ]
}

/// One finished stream as it crossed the wire: global id, committed
/// tokens, per-stream digest (hex), finish reason.
type Stream = (u64, Vec<u32>, String, String);

fn parse_done(line: &str) -> Stream {
    let v = Json::parse(line).unwrap_or_else(|e| panic!("bad line {line}: {e}"));
    if let Some(e) = v.get("error") {
        panic!("request failed: {e:?}");
    }
    let tokens = v
        .arr("tokens")
        .unwrap()
        .iter()
        .map(|t| t.as_usize().unwrap() as u32)
        .collect();
    (
        v.u("id").unwrap() as u64,
        tokens,
        v.s("stream_digest").unwrap().to_string(),
        v.s("finish_reason").unwrap().to_string(),
    )
}

fn drain_done(rx: &Receiver<ConnEvent>) -> String {
    loop {
        match rx.recv().expect("reply channel closed without Done") {
            ConnEvent::Done(line) => return line,
            ConnEvent::Accepted(_) | ConnEvent::Line(_) => {}
        }
    }
}

/// Submit `reqs` sequentially (global ids are then a pure function of
/// submission order), drain every stream, and return the sorted streams
/// plus the router's fleet digest and fold count.
fn run_fleet(
    dir: &str,
    cfg: &EngineConfig,
    reqs: Vec<Request>,
) -> (Vec<Stream>, u64, u64) {
    let router = Router::new(dir, cfg, tok());
    let mut rxs = Vec::with_capacity(reqs.len());
    for r in reqs {
        let (tx, rx) = mpsc::channel();
        router.submit(r, tx);
        rxs.push(rx);
    }
    let mut outs: Vec<Stream> =
        rxs.iter().map(|rx| parse_done(&drain_done(rx))).collect();
    outs.sort();
    let c = router.counters();
    router.join();
    (outs, c.fleet_digest, c.fleet_seqs)
}

#[test]
fn committed_streams_are_bitwise_identical_across_replica_counts() {
    // The acceptance matrix: replicas {1, 2, 4} x all three scheduler
    // policies x prefix cache on/off x verify policy {stall, margin-gate}.
    // Streams are keyed by global id, so "identical" means the same
    // request (by submission order) produced the same bytes — and the
    // fleet digest, which folds (global id, stream digest) pairs, must
    // come out equal as a single-line summary of the same fact.
    let dir = artifacts_dir();
    for policy in [
        PolicyKind::PrefillFirst,
        PolicyKind::DeadlineAware,
        PolicyKind::FairShare,
    ] {
        for cache in [false, true] {
            for vp in [VerifyPolicyKind::Stall, VerifyPolicyKind::MarginGate] {
                let mut cfg = base_cfg();
                cfg.policy = policy;
                cfg.prefix_cache = cache;
                cfg.verify_policy = VerifyPolicy::new(vp);
                cfg.replicas = 1;
                let base = run_fleet(&dir, &cfg, det_workload());
                assert_eq!(base.0.len(), 4);
                assert!(base.0.iter().all(|(_, t, _, _)| !t.is_empty()));
                assert_eq!(
                    base.2, 4,
                    "every deterministic stream must fold into the fleet digest"
                );
                for replicas in [2usize, 4] {
                    cfg.replicas = replicas;
                    let got = run_fleet(&dir, &cfg, det_workload());
                    assert_eq!(
                        base, got,
                        "replicas={replicas} {policy:?} cache={cache} {vp:?}: \
                         diverged from the single-replica run"
                    );
                }
            }
        }
    }
}

#[test]
fn forced_rollbacks_are_replica_count_invariant() {
    // Fault injection forces a verifier mismatch on every verify lane of
    // every replica — maximum rollback pressure. Rollbacks replay and
    // rewrite speculative tokens *before* they commit, so the wire
    // streams and fleet digest stay bitwise identical at every count.
    let dir = artifacts_dir();
    let mut cfg = base_cfg();
    cfg.fault = FaultPlan::EveryNthLane { every: 1, at_index: 0 };
    cfg.replicas = 1;
    let base = run_fleet(&dir, &cfg, det_workload());
    assert!(base.0.iter().all(|(_, t, _, _)| !t.is_empty()));
    for replicas in [2usize, 4] {
        cfg.replicas = replicas;
        let got = run_fleet(&dir, &cfg, det_workload());
        assert_eq!(
            base, got,
            "replicas={replicas}: rollback story diverged from one replica"
        );
    }
    // the fault genuinely fired: visible in the merged stats surface
    cfg.replicas = 2;
    let router = Router::new(&dir, &cfg, tok());
    let mut rxs = Vec::new();
    for r in det_workload() {
        let (tx, rx) = mpsc::channel();
        router.submit(r, tx);
        rxs.push(rx);
    }
    for rx in &rxs {
        let _ = drain_done(rx);
    }
    let stats = Json::parse(&router.stats()).unwrap();
    assert!(
        stats.u("rollbacks").unwrap() > 0,
        "EveryNthLane must force rollbacks: {stats:?}"
    );
    router.join();
}

#[test]
fn dead_replica_drains_from_rotation_without_disturbing_the_rest() {
    let dir = artifacts_dir();

    // undisturbed control: same workload, same replica count, no fault
    let mk_reqs = || -> Vec<Request> {
        (0..6u32)
            .map(|i| Request {
                prompt: (10 + i * 20..10 + i * 20 + 8).collect(),
                max_new_tokens: 40,
                deterministic: true,
                temperature: 1.0,
                seed: 100 + i as u64,
                ..Default::default()
            })
            .collect()
    };
    let mut cfg = base_cfg();
    cfg.replicas = 3;
    cfg.router_affinity = false; // spread-by-load placement
    cfg.eos_token = 9999; // no natural EOS: budgets run to completion
    let control = run_fleet(&dir, &cfg, mk_reqs());
    assert_eq!(control.0.len(), 6);

    // poison exactly replica 1: it fails on its 3rd engine step
    cfg.fault = FaultPlan::FailStepAt { at_step: 3 };
    cfg.fault_replica = Some(1);
    let router = Router::new(&dir, &cfg, tok());
    let mut rxs = Vec::new();
    for r in mk_reqs() {
        let (tx, rx) = mpsc::channel();
        router.submit(r, tx);
        rxs.push(rx);
    }
    let mut errored = 0usize;
    let mut survived: Vec<Stream> = Vec::new();
    for rx in &rxs {
        let line = drain_done(rx);
        let v = Json::parse(&line).unwrap();
        if let Some(e) = v.get("error") {
            // the dead replica's in-flight requests fail loudly
            assert_eq!(v.s("finish_reason").unwrap(), "error", "{line}");
            assert!(
                e.as_str().unwrap().contains("engine failed"),
                "error must carry the step failure: {line}"
            );
            errored += 1;
        } else {
            survived.push(parse_done(&line));
        }
    }
    assert!(
        errored >= 1,
        "least-loaded placement over 3 replicas must land work on the \
         poisoned one"
    );
    assert_eq!(errored + survived.len(), 6);

    // survivors are bitwise identical to the undisturbed run, matched by
    // global id (ids are submission-order, identical in both runs)
    for s in &survived {
        let c = control
            .0
            .iter()
            .find(|c| c.0 == s.0)
            .expect("control run has every id");
        assert_eq!(c, s, "a live replica's stream changed because a \
                          *different* replica died");
    }

    // The fleet is degraded, not poisoned. The error Done lines are sent
    // a hair before the replica marks itself dead, so give the drain a
    // bounded moment to land.
    let deadline = std::time::Instant::now() + std::time::Duration::from_secs(10);
    while router.counters().live_replicas != 2 {
        assert!(std::time::Instant::now() < deadline, "replica 1 never drained");
        std::thread::yield_now();
    }
    let c = router.counters();
    assert_eq!(c.replicas, 3);
    assert_eq!(c.live_replicas, 2);
    assert!(!router.poisoned());
    let stats = Json::parse(&router.stats()).unwrap();
    let per = stats.req("router").unwrap().arr("per_replica").unwrap();
    assert_eq!(per.len(), 3);
    let lives: Vec<bool> = per
        .iter()
        .map(|e| e.req("live").unwrap().as_bool().unwrap())
        .collect();
    assert_eq!(lives, vec![true, false, true]);

    // new submissions route around the corpse
    let (tx, rx) = mpsc::channel();
    router.submit(
        Request {
            prompt: (300..308).collect(),
            max_new_tokens: 6,
            deterministic: true,
            temperature: 0.0,
            seed: 0,
            ..Default::default()
        },
        tx,
    );
    let after = parse_done(&drain_done(&rx));
    assert!(!after.1.is_empty());

    // cancel resolves the owning replica regardless of which one it is:
    // park a long request, cancel it by global id from "outside"
    let (tx, rx) = mpsc::channel();
    router.submit(
        Request {
            prompt: (400..408).collect(),
            max_new_tokens: 200,
            deterministic: true,
            temperature: 1.0,
            seed: 77,
            ..Default::default()
        },
        tx,
    );
    let gid = loop {
        match rx.recv().unwrap() {
            ConnEvent::Accepted(id) => break id,
            ConnEvent::Done(line) => panic!("finished before accept: {line}"),
            ConnEvent::Line(_) => {}
        }
    };
    let ack = Json::parse(&router.cancel(gid)).unwrap();
    assert_eq!(ack.u("id").unwrap() as u64, gid);
    assert!(ack.req("cancelled").unwrap().as_bool().unwrap(), "{ack:?}");
    let fin = parse_done(&drain_done(&rx));
    assert_eq!(fin.3, "cancelled");
    // cancelling a finished / unknown id is an acknowledged no-op
    let ack = Json::parse(&router.cancel(gid)).unwrap();
    assert!(!ack.req("cancelled").unwrap().as_bool().unwrap());
    let ack = Json::parse(&router.cancel(999_999)).unwrap();
    assert!(!ack.req("cancelled").unwrap().as_bool().unwrap());

    router.join();
}

#[test]
fn all_replicas_dead_reports_poisoned_like_the_single_engine() {
    let dir = artifacts_dir();
    let mut cfg = base_cfg();
    cfg.replicas = 2;
    cfg.fault = FaultPlan::FailStepAt { at_step: 2 };
    // fault_replica = None: every replica carries the fault plan
    let router = Router::new(&dir, &cfg, tok());
    let mut rxs = Vec::new();
    for i in 0..4u32 {
        let (tx, rx) = mpsc::channel();
        router.submit(
            Request {
                prompt: (10 + i..18 + i).collect(),
                max_new_tokens: 30,
                deterministic: true,
                temperature: 1.0,
                seed: i as u64,
                ..Default::default()
            },
            tx,
        );
        rxs.push(rx);
    }
    for rx in &rxs {
        let v = Json::parse(&drain_done(rx)).unwrap();
        assert!(v.get("error").is_some(), "every request must fail: {v:?}");
    }
    // join first: the replica threads finish their mark_dead bookkeeping
    // before exiting, so the poisoned flag is settled afterwards
    router.join();
    assert!(router.poisoned());
    let stats = Json::parse(&router.stats()).unwrap();
    assert!(
        stats.s("error").unwrap().contains("poisoned"),
        "poisoned fleet stats: {stats:?}"
    );
    // routing rejects new work without any live thread in the loop
    let (tx, rx) = mpsc::channel();
    router.submit(Request::greedy(vec![5, 6], 2, false), tx);
    let v = Json::parse(&drain_done(&rx)).unwrap();
    assert!(v.s("error").unwrap().contains("poisoned"), "{v:?}");
}

#[test]
fn affinity_soak_multiturn_churn_hits_and_never_leaks() {
    // 10k-request multiturn churn through 4 replicas: 40 sessions, 250
    // turns each, submitted in per-turn waves. Every session's turn
    // shares its 32-token prefix (two complete KV blocks) with the
    // previous turn, so after the first turn, prefix-affinity should pin
    // the session to one replica.
    let dir = artifacts_dir();
    let mut cfg = base_cfg();
    cfg.mode = Mode::NonDeterministic; // cheapest path: churn, not determinism
    cfg.replicas = 4;
    cfg.prefix_cache = true;
    cfg.router_queue = 4096; // never shed in this phase
    cfg.eos_token = 9999;
    let router = Router::new(&dir, &cfg, tok());

    let sessions = 40usize;
    let turns = 250usize;
    let prefix = |s: usize| -> Vec<u32> {
        (0..32).map(|i| (40 + s * 32 + i) as u32 % 400 + 3).collect()
    };
    let mut served = 0usize;
    for t in 0..turns {
        let mut rxs = Vec::with_capacity(sessions);
        for s in 0..sessions {
            let mut prompt = prefix(s);
            // the turn-specific tail lives in a partial block: it never
            // changes the complete-block prefix hashes
            prompt.extend([(t % 300) as u32 + 5, (s % 300) as u32 + 5]);
            let (tx, rx) = mpsc::channel();
            router.submit(Request::greedy(prompt, 1, false), tx);
            rxs.push(rx);
        }
        for rx in &rxs {
            let v = Json::parse(&drain_done(rx)).unwrap();
            assert!(v.get("error").is_none(), "churn request failed: {v:?}");
            served += 1;
        }
    }
    assert_eq!(served, sessions * turns);
    assert_eq!(served, 10_000, "the soak must actually be 10k requests");

    let c = router.counters();
    assert_eq!(c.routed, served as u64);
    assert_eq!(c.shed, 0, "nothing sheds under an uncontended queue");
    // Round-robin / least-loaded placement would co-locate a session's
    // next turn with probability ~1/replicas = 0.25. Affinity must beat
    // that decisively; structurally every turn after a session's first is
    // a hit, so the rate should approach (turns-1)/turns.
    let hit_rate = c.affinity_hits as f64 / c.routed as f64;
    assert!(
        hit_rate > 0.9,
        "affinity hit rate {hit_rate:.3} not above round-robin baseline 0.25"
    );

    // zero KV leaks per replica: everything drained, every page returned
    for (i, (live, snap)) in router.snapshots().into_iter().enumerate() {
        assert!(live, "replica {i} died during the soak");
        let snap = snap.expect("live replica answers the snapshot poll");
        assert_eq!(snap.kv.held_pages, 0, "replica {i} leaked KV pages");
        assert_eq!(snap.metrics.live_seqs, 0, "replica {i} holds live seqs");
        assert!(
            snap.metrics.steps > 0,
            "replica {i} never served anything — placement is broken"
        );
    }
    router.join();
}

#[test]
fn backpressure_sheds_with_overloaded_on_the_wire() {
    let dir = artifacts_dir();
    let mut cfg = base_cfg();
    cfg.replicas = 2;
    cfg.router_queue = 2; // p0 threshold = 1, p>=2 threshold = 2
    cfg.router_affinity = false;
    cfg.eos_token = 9999;
    let router = Router::new(&dir, &cfg, tok());

    // fill both replicas to the p0 threshold with long-running requests
    let long = |seed: u64| Request {
        prompt: (10..26).collect(),
        max_new_tokens: 100,
        deterministic: true,
        temperature: 1.0,
        seed,
        ..Default::default()
    };
    let mut fillers = Vec::new();
    for i in 0..2 {
        let (tx, rx) = mpsc::channel();
        router.submit(long(i), tx);
        fillers.push(rx);
    }

    // every further p0 request sheds immediately, with the synthesized
    // wire shape: overloaded, zero tokens, the empty stream digest
    for i in 0..4u64 {
        let (tx, rx) = mpsc::channel();
        router.submit(long(50 + i), tx);
        let v = Json::parse(&drain_done(&rx)).unwrap();
        assert_eq!(v.s("finish_reason").unwrap(), "overloaded", "{v:?}");
        assert!(v.arr("tokens").unwrap().is_empty());
        assert_eq!(
            v.s("stream_digest").unwrap(),
            llm42::obs::digest_hex(DIGEST_EMPTY)
        );
    }

    // priority classes shed from the bottom: a p2 request still routes at
    // the same occupancy that shed the p0s
    let (tx, rx) = mpsc::channel();
    let mut urgent = long(99);
    urgent.priority = 2;
    urgent.max_new_tokens = 4;
    router.submit(urgent, tx);
    let v = Json::parse(&drain_done(&rx)).unwrap();
    assert!(
        v.get("error").is_none()
            && v.s("finish_reason").unwrap() != "overloaded",
        "p2 must clear the p0 shed threshold: {v:?}"
    );

    // counters + merged stats agree with what crossed the wire
    let c = router.counters();
    assert_eq!(c.shed, 4);
    assert_eq!(c.routed, 3);
    for rx in &fillers {
        let _ = drain_done(rx);
    }
    let stats = Json::parse(&router.stats()).unwrap();
    let fr = stats.req("finish_reasons").unwrap();
    assert_eq!(fr.u("overloaded").unwrap(), 4);
    let r = stats.req("router").unwrap();
    assert_eq!(r.u("shed").unwrap(), 4);
    assert_eq!(r.u("replicas").unwrap(), 2);
    router.join();
}

#[test]
fn router_stats_aggregate_replicas_and_expose_the_fleet_digest() {
    let dir = artifacts_dir();
    let mut cfg = base_cfg();
    cfg.replicas = 2;
    let router = Router::new(&dir, &cfg, tok());
    let mut rxs = Vec::new();
    for r in det_workload() {
        let (tx, rx) = mpsc::channel();
        router.submit(r, tx);
        rxs.push(rx);
    }
    let streams: Vec<Stream> =
        rxs.iter().map(|rx| parse_done(&drain_done(rx))).collect();
    assert_eq!(streams.len(), 4);

    let stats = Json::parse(&router.stats()).unwrap();
    let r = stats.req("router").unwrap();
    assert_eq!(r.u("replicas").unwrap(), 2);
    assert_eq!(r.u("live_replicas").unwrap(), 2);
    assert_eq!(r.u("routed").unwrap(), 4);
    assert_eq!(r.u("fleet_sequences").unwrap(), 4);
    assert_eq!(
        r.s("fleet_digest").unwrap(),
        llm42::obs::digest_hex(router.fleet_digest())
    );
    let per = r.arr("per_replica").unwrap();
    assert_eq!(per.len(), 2);
    let mut per_committed = 0usize;
    for e in per {
        assert!(e.req("live").unwrap().as_bool().unwrap());
        assert!(e.get("engine_digest").is_some());
        assert!(e.get("kv_available_pages").is_some());
        per_committed += e.u("committed_tokens").unwrap();
    }
    // the merged engine counters are the sum of the per-replica ones
    assert_eq!(stats.u("committed_tokens").unwrap(), per_committed);
    assert!(per_committed > 0);

    // Prometheus exposition carries the router series
    let m = Json::parse(&router.metrics()).unwrap();
    let body = m.s("metrics").unwrap();
    assert!(body.contains("llm42_router_replicas 2"));
    assert!(body.contains("llm42_router_routed_total 4"));
    assert!(body.contains("llm42_router_shed_total 0"));
    assert!(body.contains("llm42_router_fleet_digest_info{digest=\"0x"));
    router.join();
}
