//! Property-style integration tests: seeded random workloads through the
//! real engine, asserting global invariants. (The vendored crate set has
//! no proptest; these sweeps play that role with explicit seeds so every
//! failure is reproducible.)

use llm42::engine::{Engine, EngineConfig, FaultPlan, Mode, Request};
use llm42::prelude::*;
use llm42::util::rng::SplitMix64;

fn artifacts_dir() -> String {
    let dir = std::env::var("LLM42_ARTIFACTS").unwrap_or_else(|_| "artifacts".into());
    llm42::aot::ensure(&dir).expect("artifact generation failed");
    dir
}

fn random_request(rng: &mut SplitMix64, vocab: usize) -> Request {
    let plen = 1 + rng.below(40) as usize;
    Request {
        prompt: (0..plen).map(|_| 3 + rng.below(vocab as u64 - 3) as u32).collect(),
        max_new_tokens: 1 + rng.below(48) as usize,
        deterministic: rng.next_f64() < 0.5,
        temperature: if rng.next_f64() < 0.3 { 0.0 } else { 1.0 },
        seed: rng.next_u64(),
        ..Default::default()
    }
}

#[test]
fn random_workloads_complete_with_invariants() {
    let mut rt = Runtime::load(artifacts_dir()).unwrap();
    let vocab = rt.dims().vocab;

    for case in 0..3u64 {
        let mut rng = SplitMix64::new(1000 + case);
        let cfg = EngineConfig {
            mode: Mode::Llm42,
            verify_group: [1, 2, 4][case as usize % 3],
            verify_window: 16,
            max_stall_steps: 3,
            fault: if case == 2 {
                // periodic forced mismatches stress the rollback path
                FaultPlan::EveryNthLane { every: 3, at_index: 1 }
            } else {
                FaultPlan::None
            },
            ..Default::default()
        };
        let n = 8;
        let mut eng = Engine::new(&mut rt, cfg).unwrap();
        let reqs: Vec<Request> =
            (0..n).map(|_| random_request(&mut rng, vocab)).collect();
        let mut expected: std::collections::HashMap<u64, &Request> =
            Default::default();
        for r in &reqs {
            let id = eng.submit(r.clone()).unwrap();
            expected.insert(id, r);
        }
        eng.run_to_completion().unwrap();
        let outs = eng.take_finished();

        // invariant: every submitted request finishes exactly once
        assert_eq!(outs.len(), n, "case {case}");
        for o in &outs {
            let req = expected[&o.id];
            // invariant: length budget respected
            assert!(o.tokens.len() <= req.max_new_tokens, "case {case}");
            assert!(!o.tokens.is_empty(), "case {case}");
            // invariant: EOS only as the final token
            let eos_positions: Vec<usize> = o
                .tokens
                .iter()
                .enumerate()
                .filter(|(_, &t)| t == 1)
                .map(|(i, _)| i)
                .collect();
            if let Some(&p) = eos_positions.first() {
                assert_eq!(p, o.tokens.len() - 1, "case {case}: EOS mid-stream");
                assert_eq!(o.finish_reason, FinishReason::Eos);
            }
            // invariant: finish reason consistent with budget
            if o.finish_reason == FinishReason::Length {
                assert_eq!(o.tokens.len(), req.max_new_tokens, "case {case}");
            }
            // invariant: all tokens in vocab
            assert!(o.tokens.iter().all(|&t| (t as usize) < vocab));
            // invariant: rollbacks imply recomputed tokens (and vice versa)
            assert_eq!(
                o.metrics.rollbacks > 0,
                o.metrics.recomputed_tokens > 0,
                "case {case}"
            );
            // invariant: committed never exceeds what the fast path +
            // verifier produced
            assert!(
                o.metrics.decoded_tokens as usize + o.metrics.verify_passes as usize
                    >= o.tokens.len().saturating_sub(1),
                "case {case}"
            );
        }

        // determinism invariant: re-running the whole workload reproduces
        // every deterministic request's output bitwise
        let mut eng2 = Engine::new(&mut rt, EngineConfig {
            fault: FaultPlan::None,
            ..eng_cfg_of(case)
        })
        .unwrap();
        let mut map2 = std::collections::HashMap::new();
        for r in &reqs {
            let id = eng2.submit(r.clone()).unwrap();
            map2.insert(id, r.clone());
        }
        eng2.run_to_completion().unwrap();
        let outs2 = eng2.take_finished();
        // ids restart per engine; align by submission order
        let mut a: Vec<_> = outs.iter().collect();
        let mut b: Vec<_> = outs2.iter().collect();
        a.sort_by_key(|o| o.id);
        b.sort_by_key(|o| o.id);
        for (x, y) in a.iter().zip(&b) {
            if x.deterministic && map2[&y.id].deterministic {
                // same engine config modulo fault plan: fault-free and
                // faulted runs must agree on deterministic outputs
                if case != 2 {
                    assert_eq!(x.tokens, y.tokens, "case {case} req {}", x.id);
                }
            }
        }
    }
}

fn eng_cfg_of(case: u64) -> EngineConfig {
    EngineConfig {
        mode: Mode::Llm42,
        verify_group: [1, 2, 4][case as usize % 3],
        verify_window: 16,
        max_stall_steps: 3,
        ..Default::default()
    }
}

#[test]
fn slot_churn_reuses_capacity() {
    // more requests than slots: the allocator must recycle slots and the
    // queue must drain without starvation
    let mut rt = Runtime::load(artifacts_dir()).unwrap();
    let user_slots = rt.dims().slots - 1;
    let n = user_slots * 2 + 3;
    let cfg = EngineConfig {
        mode: Mode::NonDeterministic,
        verify_window: 16,
        ..Default::default()
    };
    let mut eng = Engine::new(&mut rt, cfg).unwrap();
    let mut rng = SplitMix64::new(7);
    for _ in 0..n {
        let plen = 1 + rng.below(20) as usize;
        eng.submit(Request {
            prompt: (0..plen).map(|_| 5).collect(),
            max_new_tokens: 6,
            deterministic: false,
            temperature: 0.0,
            seed: 0,
            ..Default::default()
        })
        .unwrap();
    }
    eng.run_to_completion().unwrap();
    assert_eq!(eng.take_finished().len(), n);
}

#[test]
fn verify_group_packing_does_not_change_outputs() {
    // grouped verification (G=4) and ungrouped (G=1) must commit the same
    // streams — grouping is a performance choice, not a semantic one
    // (lane-position invariance, paper O2/O3).
    let mut rt = Runtime::load(artifacts_dir()).unwrap();
    let reqs: Vec<Request> = (0..4)
        .map(|i| Request {
            prompt: (10 + i..30 + i).collect(),
            max_new_tokens: 30,
            deterministic: true,
            temperature: 1.0,
            seed: 77 + i as u64,
            ..Default::default()
        })
        .collect();

    let mut run = |rt: &mut Runtime, group: usize| -> Vec<Vec<u32>> {
        let cfg = EngineConfig {
            mode: Mode::Llm42,
            verify_group: group,
            verify_window: 16,
            max_stall_steps: 2,
            ..Default::default()
        };
        let mut eng = Engine::new(rt, cfg).unwrap();
        for r in &reqs {
            eng.submit(r.clone()).unwrap();
        }
        eng.run_to_completion().unwrap();
        let mut outs = eng.take_finished();
        outs.sort_by_key(|o| o.id);
        outs.into_iter().map(|o| o.tokens).collect()
    };

    let grouped = run(&mut rt, 4);
    let ungrouped = run(&mut rt, 1);
    assert_eq!(grouped, ungrouped);
}
