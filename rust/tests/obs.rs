//! Determinism-provenance integration tests: the stream-digest chains, the
//! engine-wide digest fold, rollback forensics, and the event journal —
//! and the layer's core promise that recording never changes committed
//! streams. The digest matrix sweeps thread count x policy x prefix cache
//! x obs level and pins one engine digest for all of it.
//!
//! Requires `make artifacts` (the tiny-preset artifact set).

use std::sync::Mutex;

use llm42::engine::{Engine, EngineConfig, FaultPlan, Mode, PolicyKind, Request};
use llm42::obs::{
    digest_stream, Event, EventBody, ObsConfig, ObsLevel, RollbackForensics,
};
use llm42::prelude::*;
use llm42::util::json::Json;

/// The worker-thread knob is process-global; the matrix test sweeps it and
/// holds this gate so its runs don't interleave with each other.
static GATE: Mutex<()> = Mutex::new(());

fn gate() -> std::sync::MutexGuard<'static, ()> {
    GATE.lock().unwrap_or_else(|e| e.into_inner())
}

fn artifacts_dir() -> String {
    let dir = std::env::var("LLM42_ARTIFACTS").unwrap_or_else(|_| "artifacts".into());
    llm42::aot::ensure(&dir).expect("artifact generation failed");
    dir
}

/// All-deterministic workload with a shared 32-token prefix (two full KV
/// blocks, so the prefix cache genuinely adopts pages when enabled): the
/// committed streams — and therefore every digest — are policy-, cache-,
/// thread-, and obs-level-invariant by the determinism contract.
fn audited_workload() -> Vec<Request> {
    let shared: Vec<u32> = (100..132).collect();
    let mk = |extra: u32, n: usize, seed: u64| {
        let mut prompt = shared.clone();
        prompt.extend(extra..extra + 4);
        Request {
            prompt,
            max_new_tokens: n,
            deterministic: true,
            temperature: 1.0,
            seed,
            ..Default::default()
        }
    };
    vec![
        mk(200, 20, 11),
        mk(210, 16, 12),
        mk(220, 12, 13),
        Request {
            prompt: (10..22).collect(),
            max_new_tokens: 18,
            deterministic: true,
            temperature: 0.0,
            seed: 0,
            ..Default::default()
        },
    ]
}

/// Run the audited workload under one configuration; return every stream
/// with its digest (sorted by id), the engine-wide digest, and how many
/// streams folded into it.
fn run_audited(
    rt: &mut Runtime,
    threads: usize,
    policy: PolicyKind,
    cache: bool,
    level: ObsLevel,
) -> (Vec<(u64, Vec<u32>, u64)>, u64, u64) {
    let cfg = EngineConfig {
        mode: Mode::Llm42,
        verify_group: 2,
        verify_window: 16,
        max_stall_steps: 4,
        policy,
        prefix_cache: cache,
        threads,
        obs: ObsConfig { level, ..Default::default() },
        ..Default::default()
    };
    let mut eng = Engine::new(rt, cfg).unwrap();
    for r in audited_workload() {
        eng.submit(r).unwrap();
    }
    eng.run_to_completion().unwrap();
    let engine_digest = eng.obs.engine_digest();
    let folded = eng.obs.digest_seqs();
    let mut outs: Vec<(u64, Vec<u32>, u64)> = eng
        .take_finished()
        .into_iter()
        .map(|o| (o.id, o.tokens, o.stream_digest))
        .collect();
    outs.sort();
    (outs, engine_digest, folded)
}

#[test]
fn digests_pin_the_determinism_matrix() {
    // Threads {1, 4} x all three policies x prefix cache on/off x obs
    // {off, events}: one set of streams, one set of stream digests, one
    // engine digest. Each run also checks the provenance invariant that
    // the running chain equals a from-scratch digest of the stream.
    let _g = gate();
    let mut rt = Runtime::load(artifacts_dir()).unwrap();
    let mut baseline: Option<(Vec<(u64, Vec<u32>, u64)>, u64)> = None;
    for policy in [
        PolicyKind::PrefillFirst,
        PolicyKind::DeadlineAware,
        PolicyKind::FairShare,
    ] {
        for cache in [false, true] {
            for level in [ObsLevel::Off, ObsLevel::Events] {
                for threads in [1usize, 4] {
                    let (outs, digest, folded) =
                        run_audited(&mut rt, threads, policy, cache, level);
                    let tag = format!(
                        "{policy:?} cache={cache} obs={} threads={threads}",
                        level.as_str()
                    );
                    assert_eq!(outs.len(), 4, "{tag}: all requests finish");
                    assert_eq!(folded, 4, "{tag}: every served stream folds in");
                    assert_ne!(digest, 0, "{tag}: fold is non-trivial");
                    for (id, tokens, d) in &outs {
                        assert!(!tokens.is_empty(), "{tag}: request {id} committed");
                        assert_eq!(
                            *d,
                            digest_stream(tokens),
                            "{tag}: request {id}: running digest chain diverged \
                             from the committed stream"
                        );
                    }
                    match &baseline {
                        None => baseline = Some((outs, digest)),
                        Some((b_outs, b_digest)) => {
                            assert_eq!(
                                b_outs, &outs,
                                "{tag}: streams/digests diverged from baseline"
                            );
                            assert_eq!(
                                *b_digest, digest,
                                "{tag}: engine digest diverged from baseline"
                            );
                        }
                    }
                }
            }
        }
    }
    rt.set_sim_threads(0);
}

/// Run with fault injection forcing a rollback at window row 0 on every
/// verify lane; return the streams, the rollback count, and the captured
/// forensics ring.
fn run_faulted(
    rt: &mut Runtime,
    level: ObsLevel,
) -> (Vec<(u64, Vec<u32>)>, u64, Vec<RollbackForensics>) {
    let cfg = EngineConfig {
        mode: Mode::Llm42,
        verify_group: 2,
        verify_window: 16,
        max_stall_steps: 4,
        fault: FaultPlan::EveryNthLane { every: 1, at_index: 0 },
        obs: ObsConfig { level, ..Default::default() },
        ..Default::default()
    };
    let mut eng = Engine::new(rt, cfg).unwrap();
    for r in audited_workload() {
        eng.submit(r).unwrap();
    }
    eng.run_to_completion().unwrap();
    let rollbacks = eng.metrics.rollbacks;
    let forensics: Vec<RollbackForensics> = eng.obs.forensics().cloned().collect();
    let mut outs: Vec<(u64, Vec<u32>)> =
        eng.take_finished().into_iter().map(|o| (o.id, o.tokens)).collect();
    outs.sort();
    (outs, rollbacks, forensics)
}

#[test]
fn forensics_name_the_divergence_and_recording_never_steers() {
    let mut rt = Runtime::load(artifacts_dir()).unwrap();
    let (outs_off, rb_off, forensics_off) = run_faulted(&mut rt, ObsLevel::Off);
    assert!(rb_off > 0, "fault injection must force rollbacks");
    assert!(forensics_off.is_empty(), "off level records no forensics");

    // counters level captures the forensics ring — and changes nothing
    // about the engine's behavior, even under maximum rollback pressure
    let (outs, rb, forensics) = run_faulted(&mut rt, ObsLevel::Counters);
    assert_eq!(outs_off, outs, "recording changed committed streams");
    assert_eq!(rb_off, rb, "recording changed rollback behavior");
    assert_eq!(forensics.len() as u64, rb, "one forensics entry per rollback");
    for f in &forensics {
        assert_eq!(f.divergence, 0, "at_index 0 faults diverge at window row 0");
        assert_ne!(f.expected, f.observed, "a divergence is a token disagreement");
        assert!(f.discarded >= 1, "the diverged speculation was discarded");
        assert!(f.margin.is_finite(), "divergence-row margin is captured");
        let (_, tokens) = outs.iter().find(|(id, _)| *id == f.id).unwrap();
        if f.fresh_committed {
            assert_eq!(
                tokens[f.frontier + f.divergence],
                f.observed,
                "request {}: the corrective token must be what the stream \
                 committed at the divergence position",
                f.id
            );
        }
    }
}

#[test]
fn events_cursor_drain_is_lossless_and_ordered() {
    let mut rt = Runtime::load(artifacts_dir()).unwrap();
    let cfg = EngineConfig {
        mode: Mode::Llm42,
        verify_group: 2,
        verify_window: 16,
        max_stall_steps: 4,
        obs: ObsConfig { level: ObsLevel::Events, ..Default::default() },
        ..Default::default()
    };
    let mut eng = Engine::new(&mut rt, cfg).unwrap();
    for r in audited_workload() {
        eng.submit(r).unwrap();
    }
    // drain incrementally from a client-held cursor while stepping, the
    // way a `{"cmd":"events","since":N}` poller would
    let mut cursor = 0u64;
    let mut collected: Vec<Event> = Vec::new();
    while !eng.idle() {
        eng.step().unwrap();
        let (evs, dropped) = eng.obs.events_since(cursor);
        assert_eq!(dropped, 0, "nothing ages out of an 8192-event ring here");
        let evs: Vec<Event> = evs.into_iter().cloned().collect();
        if let Some(last) = evs.last() {
            assert!(last.seq > cursor, "drains only move the cursor forward");
            cursor = last.seq;
        }
        collected.extend(evs);
    }
    assert!(!collected.is_empty());
    for (i, e) in collected.iter().enumerate() {
        assert_eq!(
            e.seq,
            i as u64 + 1,
            "incremental drains concatenate gap-free, in order"
        );
    }
    assert_eq!(eng.obs.last_seq(), collected.len() as u64);
    // one full drain from 0 sees exactly what the incremental cursor saw
    let (full, dropped) = eng.obs.events_since(0);
    assert_eq!(dropped, 0);
    assert_eq!(full.into_iter().cloned().collect::<Vec<_>>(), collected);
    // a past-the-end cursor drains empty without claiming drops
    let (tail, dropped) = eng.obs.events_since(cursor);
    assert!(tail.is_empty());
    assert_eq!(dropped, 0);
    // the journal tells the whole lifecycle story
    assert!(collected.iter().any(|e| matches!(e.body, EventBody::Step { .. })));
    assert!(collected.iter().any(|e| matches!(e.body, EventBody::Verify { .. })));
    assert_eq!(
        collected
            .iter()
            .filter(|e| matches!(e.body, EventBody::Retire { .. }))
            .count(),
        4,
        "one retire event per request"
    );
}

#[test]
fn trace_out_writes_the_journal_as_jsonl() {
    let mut rt = Runtime::load(artifacts_dir()).unwrap();
    let path = std::env::temp_dir()
        .join(format!("llm42_obs_trace_{}.jsonl", std::process::id()));
    let cfg = EngineConfig {
        mode: Mode::Llm42,
        verify_group: 2,
        verify_window: 16,
        max_stall_steps: 4,
        obs: ObsConfig {
            // a JSONL sink must bump this to events on its own
            level: ObsLevel::Off,
            trace_out: Some(path.to_string_lossy().into_owned()),
            ..Default::default()
        },
        ..Default::default()
    };
    let mut eng = Engine::new(&mut rt, cfg).unwrap();
    assert_eq!(eng.obs.level(), ObsLevel::Events, "trace sink implies events");
    for r in audited_workload() {
        eng.submit(r).unwrap();
    }
    eng.run_to_completion().unwrap();
    let emitted = eng.obs.last_seq();
    assert!(emitted > 0);
    drop(eng); // flushes the sink's buffered tail

    let text = std::fs::read_to_string(&path).unwrap();
    let lines: Vec<&str> = text.lines().collect();
    assert_eq!(lines.len() as u64, emitted, "one JSONL line per journal event");
    for (i, line) in lines.iter().enumerate() {
        let v = Json::parse(line)
            .unwrap_or_else(|e| panic!("line {}: not JSON: {e:?}", i + 1));
        assert_eq!(v.u("seq").unwrap(), i + 1, "file order is seq order");
        assert!(v.get("event").is_some(), "line {}: typed event", i + 1);
    }
    let _ = std::fs::remove_file(&path);
}
