//! Abort correctness: `Engine::abort` (cancel / timeout) must reclaim
//! every resource a request held — in any phase, under every scheduling
//! policy, prefix cache on and off — without perturbing the committed
//! streams of other in-flight deterministic requests.

use llm42::engine::{Engine, EngineConfig, FinishReason, Mode, PolicyKind, Request};
use llm42::prelude::*;

fn artifacts_dir() -> String {
    let dir = std::env::var("LLM42_ARTIFACTS").unwrap_or_else(|_| "artifacts".into());
    llm42::aot::ensure(&dir).expect("artifact generation failed");
    dir
}

fn cfg(policy: PolicyKind, cache: bool) -> EngineConfig {
    EngineConfig {
        mode: Mode::Llm42,
        verify_group: 2,
        verify_window: 16,
        max_stall_steps: 4,
        policy,
        prefix_cache: cache,
        ..Default::default()
    }
}

fn det_req(seed: u64) -> Request {
    Request {
        prompt: (10..26).collect(),
        max_new_tokens: 40,
        deterministic: true,
        temperature: 1.0,
        seed,
        ..Default::default()
    }
}

fn bg_req(seed: u64) -> Request {
    Request {
        prompt: (30..42).collect(),
        max_new_tokens: 48,
        deterministic: false,
        temperature: 1.0,
        seed,
        ..Default::default()
    }
}

const POLICIES: [PolicyKind; 3] = [
    PolicyKind::PrefillFirst,
    PolicyKind::DeadlineAware,
    PolicyKind::FairShare,
];

#[test]
fn abort_mid_decode_and_mid_verify_reclaims_kv_under_every_policy() {
    // Cancel one deterministic lane while it holds unverified speculative
    // tokens (mid-verify window) and one non-deterministic lane mid-decode,
    // under each policy x prefix cache on/off. After drain the pool's
    // available pages (free + reclaimable cache) must equal the
    // pre-submission value and the per-reason counters must account for
    // every finish.
    let mut rt = Runtime::load(artifacts_dir()).unwrap();
    for policy in POLICIES {
        for cache in [false, true] {
            let mut eng = Engine::new(&mut rt, cfg(policy, cache)).unwrap();
            let base = eng.kv_stats();
            let det_victim = eng.submit(det_req(7)).unwrap();
            let bg_victim = eng.submit(bg_req(8)).unwrap();
            let survivor = eng.submit(det_req(9)).unwrap();

            // step until the deterministic victim is mid-window (has
            // speculative tokens awaiting verification) and the background
            // victim has committed fast-path tokens (mid-decode)
            let mut armed = false;
            for _ in 0..300 {
                eng.step().unwrap();
                let v = eng.view();
                let det_spec = v
                    .lanes
                    .iter()
                    .find(|l| l.id == det_victim)
                    .map(|l| l.speculative)
                    .unwrap_or(0);
                let bg_committed = v
                    .lanes
                    .iter()
                    .find(|l| l.id == bg_victim)
                    .map(|l| l.committed)
                    .unwrap_or(0);
                if det_spec > 0 && bg_committed > 0 {
                    armed = true;
                    break;
                }
            }
            assert!(armed, "{policy:?}/cache={cache}: victims never got in flight");

            assert!(eng.abort(det_victim, FinishReason::Cancelled).unwrap());
            assert!(eng.abort(bg_victim, FinishReason::Cancelled).unwrap());
            eng.run_to_completion().unwrap();
            assert!(eng.idle());

            let outs = eng.take_finished();
            assert_eq!(outs.len(), 3, "{policy:?}/cache={cache}");
            for id in [det_victim, bg_victim] {
                let o = outs.iter().find(|o| o.id == id).unwrap();
                assert_eq!(
                    o.finish_reason,
                    FinishReason::Cancelled,
                    "{policy:?}/cache={cache}"
                );
            }
            let surv = outs.iter().find(|o| o.id == survivor).unwrap();
            assert!(!surv.tokens.is_empty());
            assert!(!surv.finish_reason.is_abort());

            assert_eq!(eng.metrics.finished_cancelled, 2);
            assert_eq!(eng.metrics.aborted(), 2);

            // resource conservation: every page the requests held is free
            // or (with the cache on) reclaimable again
            let end = eng.kv_stats();
            assert_eq!(
                end.available_pages(),
                base.available_pages(),
                "{policy:?}/cache={cache}: KV pages leaked"
            );
            if !cache {
                // nothing is ever published with the cache off, so the
                // stronger free-count equality holds too
                assert_eq!(end.free_pages, base.free_pages);
                assert_eq!(end.cached_pages, 0);
            }
        }
    }
}

#[test]
fn abort_of_queued_requests_and_idempotence() {
    let mut rt = Runtime::load(artifacts_dir()).unwrap();
    let mut eng = Engine::new(&mut rt, cfg(PolicyKind::PrefillFirst, false)).unwrap();
    let base = eng.kv_stats();

    // overload admission so some requests stay queued
    let ids: Vec<u64> = (0..8).map(|i| eng.submit(bg_req(100 + i)).unwrap()).collect();
    eng.step().unwrap();
    let queued_id = {
        let v = eng.view();
        assert!(!v.queue.is_empty(), "workload must overflow admission");
        v.queue[0].id
    };
    assert!(ids.contains(&queued_id));

    // queued abort: leaves the queue without ever touching KV
    assert!(eng.abort(queued_id, FinishReason::Cancelled).unwrap());
    // unknown / already-finished ids are idempotent no-ops
    assert!(!eng.abort(queued_id, FinishReason::Cancelled).unwrap());
    assert!(!eng.abort(999_999, FinishReason::Cancelled).unwrap());
    // natural finishes are not abort reasons
    assert!(eng.abort(ids[0], FinishReason::Eos).is_err());
    assert!(eng.abort(ids[0], FinishReason::Length).is_err());

    eng.run_to_completion().unwrap();
    let outs = eng.take_finished();
    assert_eq!(outs.len(), ids.len());
    let cancelled = outs.iter().find(|o| o.id == queued_id).unwrap();
    assert_eq!(cancelled.finish_reason, FinishReason::Cancelled);
    assert!(cancelled.tokens.is_empty(), "queued victims never decoded");
    assert_eq!(eng.metrics.finished_cancelled, 1);
    assert_eq!(eng.kv_stats().free_pages, base.free_pages);
}

#[test]
fn cancellation_leaves_other_det_streams_bitwise_unchanged() {
    // The determinism side of the lifecycle: cancelling co-traffic midway
    // must not change a single bit of any other deterministic request's
    // committed stream, under every policy x cache setting.
    let mut rt = Runtime::load(artifacts_dir()).unwrap();
    for policy in POLICIES {
        for cache in [false, true] {
            let mut run = |rt: &mut Runtime, cancel_after: Option<usize>| {
                let mut eng = Engine::new(rt, cfg(policy, cache)).unwrap();
                let det_a = eng.submit(det_req(7)).unwrap();
                let det_b = eng.submit(det_req(21)).unwrap();
                let victim = eng.submit(bg_req(33)).unwrap();
                let mut steps = 0usize;
                while !eng.idle() {
                    eng.step().unwrap();
                    steps += 1;
                    if cancel_after == Some(steps) {
                        eng.abort(victim, FinishReason::Cancelled).unwrap();
                    }
                }
                let outs = eng.take_finished();
                let toks = |id: u64| {
                    outs.iter().find(|o| o.id == id).unwrap().tokens.clone()
                };
                (toks(det_a), toks(det_b))
            };
            let reference = run(&mut rt, None);
            let with_cancel = run(&mut rt, Some(12));
            assert_eq!(
                reference, with_cancel,
                "{policy:?}/cache={cache}: cancellation leaked into det streams"
            );
        }
    }
}

#[test]
fn timeouts_reap_live_and_queued_requests() {
    let mut rt = Runtime::load(artifacts_dir()).unwrap();
    let mut eng = Engine::new(&mut rt, cfg(PolicyKind::PrefillFirst, false)).unwrap();
    let base = eng.kv_stats();

    // a request with a loose-enough timeout to get decoding first, a
    // short-timeout one that must queue behind a full house, and untimed
    // survivors
    let survivor = eng.submit(det_req(5)).unwrap();
    let doomed_live = eng
        .submit(Request { timeout_ms: Some(1500.0), ..bg_req(61) })
        .unwrap();
    let filler_a = eng.submit(bg_req(62)).unwrap();
    let filler_b = eng.submit(bg_req(63)).unwrap();
    // seats are full (test preset: 4 user slots): this one stays queued
    let doomed_queued = eng
        .submit(Request { timeout_ms: Some(1500.0), ..bg_req(64) })
        .unwrap();

    // arm: the live victim must actually be decoding before it expires
    let mut armed = false;
    for _ in 0..40 {
        eng.step().unwrap();
        let v = eng.view();
        if v.lanes.iter().any(|l| l.id == doomed_live && l.committed > 0) {
            armed = true;
            break;
        }
    }
    assert!(armed, "live victim never started decoding");
    std::thread::sleep(std::time::Duration::from_millis(1600));
    eng.run_to_completion().unwrap();
    let outs = eng.take_finished();
    assert_eq!(outs.len(), 5);
    for id in [doomed_live, doomed_queued] {
        let o = outs.iter().find(|o| o.id == id).unwrap();
        assert_eq!(o.finish_reason, FinishReason::Timeout, "id {id}");
    }
    for id in [survivor, filler_a, filler_b] {
        let o = outs.iter().find(|o| o.id == id).unwrap();
        assert!(!o.finish_reason.is_abort(), "id {id} should finish naturally");
    }
    assert_eq!(eng.metrics.finished_timeout, 2);
    assert_eq!(eng.kv_stats().free_pages, base.free_pages);
}

#[test]
fn engine_default_timeout_applies_to_untimed_requests() {
    let mut rt = Runtime::load(artifacts_dir()).unwrap();
    let mut c = cfg(PolicyKind::PrefillFirst, false);
    c.request_timeout_ms = 5.0;
    let mut eng = Engine::new(&mut rt, c).unwrap();
    let id = eng.submit(bg_req(70)).unwrap();
    // a per-request timeout overrides the deployment default
    let roomy = eng
        .submit(Request { timeout_ms: Some(120_000.0), ..det_req(71) })
        .unwrap();
    std::thread::sleep(std::time::Duration::from_millis(20));
    eng.run_to_completion().unwrap();
    let outs = eng.take_finished();
    assert_eq!(
        outs.iter().find(|o| o.id == id).unwrap().finish_reason,
        FinishReason::Timeout
    );
    let r = outs.iter().find(|o| o.id == roomy).unwrap();
    assert!(!r.finish_reason.is_abort());
    assert!(!r.tokens.is_empty());
}
