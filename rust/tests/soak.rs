//! Churn soak: the O(live) scaling contract, end to end.
//!
//! The pre-store engine kept a tombstone per finished request, so view
//! building, stall bumping, timeout reaping, and the stream sweep were
//! O(total requests ever served) and memory grew without bound — fine for
//! a benchmark, fatal for a weeks-long server. This test pushes an order
//! of magnitude more requests through the engine than it ever holds live
//! and asserts the two halves of the contract:
//!
//! * **memory**: the sequence-store slab capacity (and live high-water
//!   mark) stay bounded by the concurrent wave size, not by the 10k
//!   cumulative requests;
//! * **work**: total steps scale linearly with requests served — a
//!   per-step scan over dead history would not change the step *count*,
//!   so the count bound is backed by the store-level guarantee that scans
//!   only walk live lanes (pinned structurally in `engine/store.rs` unit
//!   tests; the capacity bound here proves dead requests leave the store,
//!   which is what makes those scans O(live)).

use llm42::engine::{Engine, EngineConfig, Mode, Request};
use llm42::prelude::*;

fn artifacts_dir() -> String {
    let dir = std::env::var("LLM42_ARTIFACTS").unwrap_or_else(|_| "artifacts".into());
    llm42::aot::ensure(&dir).expect("artifact generation failed");
    dir
}

#[test]
fn store_stays_bounded_under_ten_thousand_request_churn() {
    let mut rt = Runtime::load(artifacts_dir()).unwrap();
    let cfg = EngineConfig {
        mode: Mode::NonDeterministic,
        eos_token: 9999, // out of vocab: every request runs its full budget
        ..Default::default()
    };
    let mut eng = Engine::new(&mut rt, cfg).unwrap();
    let _ = eng.warmup();

    // closed loop: waves of short one-token requests, drained per wave —
    // the store never holds more than `wave` live while serving 10k total
    let total = 10_000usize;
    let wave = 8usize;
    let mut submitted = 0usize;
    let mut done = 0usize;
    while done < total {
        let n = wave.min(total - submitted);
        for i in 0..n {
            let t = 3 + ((submitted + i) % 400) as u32;
            eng.submit(Request::greedy(vec![t], 1, false)).unwrap();
        }
        submitted += n;
        eng.run_to_completion().unwrap();
        done += eng.take_finished().len();
    }
    assert_eq!(done, total, "every request finishes exactly once");

    // memory half of the contract: slab capacity tracks the live HWM
    let cap = eng.metrics.store_capacity as usize;
    let hwm = eng.metrics.live_seqs_hwm as usize;
    assert!(
        hwm <= wave,
        "live HWM {hwm} must be bounded by the wave size {wave}"
    );
    assert!(
        cap <= hwm,
        "slab capacity {cap} must be bounded by the live HWM {hwm} — \
         growing with the {total} cumulative requests means tombstones are back"
    );
    assert_eq!(eng.metrics.live_seqs, 0, "drained engine holds nothing live");

    // work half: each one-token request costs one prefill forward plus
    // admission bookkeeping; steps must scale with requests, with a
    // generous constant, independent of cumulative history
    let steps = eng.metrics.steps as usize;
    assert!(
        steps <= 4 * total,
        "{steps} steps for {total} requests — per-request step cost grew"
    );

    // nothing leaks downstream either: KV fully released
    let kv = eng.kv_stats();
    assert_eq!(kv.held_pages, 0);
}
