//! Integration tests for the pluggable verification trigger
//! ([`VerifyPolicy`]) and margin-certified sparse verification.
//!
//! The margin gate's contract is the PR's headline invariant: committed
//! streams AND the engine-wide determinism digest are bitwise identical
//! with the gate on or off, across every scheduler policy, prefix-cache
//! setting, step-composer setting, and thread count. The gate may only
//! change *how many forwards* the engine runs, never what it commits.
//!
//! Requires `make artifacts` (the tiny-preset artifact set with a
//! calibrated `margin_bound`).

use llm42::engine::{
    Engine, EngineConfig, FaultPlan, Mode, PolicyKind, Request, VerifyPolicy,
    VerifyPolicyKind,
};
use llm42::prelude::*;

fn artifacts_dir() -> String {
    let dir = std::env::var("LLM42_ARTIFACTS").unwrap_or_else(|_| "artifacts".into());
    llm42::aot::ensure(&dir).expect("artifact generation failed");
    dir
}

fn cfg(kind: VerifyPolicyKind) -> EngineConfig {
    EngineConfig {
        mode: Mode::Llm42,
        verify_group: 2,
        verify_window: 16,
        max_stall_steps: 4,
        verify_policy: VerifyPolicy::new(kind),
        ..Default::default()
    }
}

/// A deterministic-only workload mixing greedy and seeded-Gumbel
/// sampling. All-deterministic matters for the digest comparison: the
/// engine digest folds every retired request's stream digest, and only
/// deterministic streams are guaranteed identical across trigger /
/// policy / cache / fusion / thread settings.
fn det_workload() -> Vec<Request> {
    vec![
        Request {
            prompt: (10..26).collect(),
            max_new_tokens: 28,
            deterministic: true,
            temperature: 0.0,
            seed: 0,
            ..Default::default()
        },
        Request {
            prompt: (40..52).collect(),
            max_new_tokens: 24,
            deterministic: true,
            temperature: 1.0,
            seed: 7,
            ..Default::default()
        },
        Request {
            prompt: (60..80).collect(),
            max_new_tokens: 20,
            deterministic: true,
            temperature: 0.0,
            seed: 0,
            ..Default::default()
        },
        Request {
            prompt: (90..104).collect(),
            max_new_tokens: 22,
            deterministic: true,
            temperature: 0.5,
            seed: 13,
            ..Default::default()
        },
    ]
}

/// Run a workload to completion; return per-request committed streams
/// (in submit order, independent of id assignment), the engine digest,
/// and the final metrics.
fn run(
    rt: &mut Runtime,
    c: EngineConfig,
    reqs: &[Request],
) -> (Vec<Vec<u32>>, u64, llm42::engine::EngineMetrics) {
    let mut eng = Engine::new(rt, c).unwrap();
    let ids: Vec<u64> =
        reqs.iter().map(|r| eng.submit(r.clone()).unwrap()).collect();
    eng.run_to_completion().unwrap();
    let outs = eng.take_finished();
    assert_eq!(outs.len(), ids.len(), "all requests must finish");
    let streams: Vec<Vec<u32>> = ids
        .iter()
        .map(|id| {
            outs.iter().find(|o| o.id == *id).expect("missing output").tokens.clone()
        })
        .collect();
    (streams, eng.obs.engine_digest(), eng.metrics.clone())
}

#[test]
fn gate_is_bitwise_invisible_across_the_full_matrix() {
    // streams + engine digest: margin-gate vs stall, across
    // 3 scheduler policies x cache {off,on} x fusion {off,on} x
    // threads {1,4}. Every one of the 48 runs must agree with the
    // canonical baseline (det streams are invariant to all of these).
    let mut rt = Runtime::load(artifacts_dir()).unwrap();
    let reqs = det_workload();

    let (base_streams, base_digest, base_m) =
        run(&mut rt, cfg(VerifyPolicyKind::Stall), &reqs);
    assert!(base_streams.iter().all(|t| !t.is_empty()));
    assert_eq!(base_m.certified_tokens, 0, "stall trigger never certifies");
    assert_eq!(base_m.gate_repair_tokens, 0);

    let mut certified_total = 0u64;
    for policy in [
        PolicyKind::PrefillFirst,
        PolicyKind::DeadlineAware,
        PolicyKind::FairShare,
    ] {
        for cache in [false, true] {
            for fusion in [0usize, 64] {
                for threads in [1usize, 4] {
                    for kind in
                        [VerifyPolicyKind::Stall, VerifyPolicyKind::MarginGate]
                    {
                        let mut c = cfg(kind);
                        c.policy = policy;
                        c.prefix_cache = cache;
                        c.max_step_tokens = fusion;
                        c.threads = threads;
                        let (streams, digest, m) = run(&mut rt, c, &reqs);
                        let tag = format!(
                            "{policy:?} cache={cache} fusion={fusion} \
                             threads={threads} trigger={}",
                            kind.name()
                        );
                        assert_eq!(streams, base_streams, "streams: {tag}");
                        assert_eq!(digest, base_digest, "digest: {tag}");
                        match kind {
                            VerifyPolicyKind::MarginGate => {
                                certified_total += m.certified_tokens;
                                // certified + verified never exceeds the
                                // committed total (prefill commits the
                                // gen-0 token outside both buckets)
                                assert!(
                                    m.certified_tokens + m.verified_tokens
                                        <= m.committed_tokens,
                                    "{tag}"
                                );
                            }
                            _ => {
                                assert_eq!(m.certified_tokens, 0, "{tag}");
                                assert_eq!(m.gate_repair_tokens, 0, "{tag}");
                            }
                        }
                    }
                }
            }
        }
    }
    // the gate must actually fire somewhere, or the whole matrix above
    // only proved that a dead feature changes nothing
    assert!(
        certified_total > 0,
        "the calibrated margin_bound certified nothing across the matrix"
    );
}

#[test]
fn gate_reduces_verification_work_on_wide_margin_traffic() {
    // the perf claim, mechanically: greedy traffic with the calibrated
    // bound certifies most tokens, so the gate runs fewer verify passes
    // and fewer forwards per committed token than the stall trigger
    let mut rt = Runtime::load(artifacts_dir()).unwrap();
    let reqs: Vec<Request> = (0..3u32)
        .map(|i| Request {
            prompt: (10 + i * 40..26 + i * 40).collect(),
            max_new_tokens: 32,
            deterministic: true,
            temperature: 0.0,
            seed: 0,
            ..Default::default()
        })
        .collect();
    let (off_streams, _, off_m) = run(&mut rt, cfg(VerifyPolicyKind::Stall), &reqs);
    let (on_streams, _, on_m) =
        run(&mut rt, cfg(VerifyPolicyKind::MarginGate), &reqs);
    assert_eq!(off_streams, on_streams);
    assert!(on_m.certified_tokens > 0);
    assert!(
        on_m.verify_passes <= off_m.verify_passes,
        "gate must not add verify passes ({} vs {})",
        on_m.verify_passes,
        off_m.verify_passes
    );
    assert!(
        on_m.forward_passes < off_m.forward_passes,
        "gate must save forwards on wide-margin traffic ({} vs {})",
        on_m.forward_passes,
        off_m.forward_passes
    );
    assert!(
        on_m.forwards_per_committed_token() < off_m.forwards_per_committed_token()
    );
}

#[test]
fn gate_streams_survive_nondeterministic_cotraffic() {
    // mixed traffic: deterministic streams compare gate on vs off even
    // when nondet co-traffic perturbs bucket trajectories (the engine
    // digest is NOT compared here — nondet streams legitimately depend
    // on scheduling, which the gate changes)
    let mut rt = Runtime::load(artifacts_dir()).unwrap();
    let mut reqs = det_workload();
    reqs.push(Request {
        prompt: (30..42).collect(),
        max_new_tokens: 40,
        deterministic: false,
        temperature: 1.0,
        seed: 100,
        ..Default::default()
    });
    reqs.push(Request {
        prompt: (120..132).collect(),
        max_new_tokens: 16,
        deterministic: false,
        temperature: 1.0,
        seed: 101,
        ..Default::default()
    });
    // streams come back in submit order: the first four are det
    let (off, _, _) = run(&mut rt, cfg(VerifyPolicyKind::Stall), &reqs);
    let (on, _, _) = run(&mut rt, cfg(VerifyPolicyKind::MarginGate), &reqs);
    assert_eq!(off[..4], on[..4]);
}

#[test]
fn slack_trigger_is_also_bitwise_invisible() {
    // the Slack trigger fires verification earlier for deadline-tight
    // lanes under every scheduler policy; like the gate it may only move
    // work, never results
    let mut rt = Runtime::load(artifacts_dir()).unwrap();
    let mut reqs = det_workload();
    for (i, r) in reqs.iter_mut().enumerate() {
        r.deadline_ms = Some(50.0 + 100.0 * i as f64);
    }
    let (base, base_digest, _) = run(&mut rt, cfg(VerifyPolicyKind::Stall), &reqs);
    for policy in [PolicyKind::PrefillFirst, PolicyKind::DeadlineAware] {
        let mut c = cfg(VerifyPolicyKind::Slack);
        c.policy = policy;
        let (streams, digest, m) = run(&mut rt, c, &reqs);
        assert_eq!(streams, base, "{policy:?}");
        assert_eq!(digest, base_digest, "{policy:?}");
        assert_eq!(m.certified_tokens, 0, "slack never certifies");
    }
}

#[test]
fn forced_mismatches_roll_back_only_uncertified_tokens() {
    // fault injection forces every verify window to report a mismatch at
    // position 0 — maximum rollback pressure. Under the gate, certified
    // tokens are already committed and committed tokens are append-only,
    // so the stream still equals the clean gate-off run: rollbacks can
    // only ever discard speculative (uncertified) tokens.
    let mut rt = Runtime::load(artifacts_dir()).unwrap();
    let reqs = det_workload();

    let (clean, _, _) = run(&mut rt, cfg(VerifyPolicyKind::Stall), &reqs);

    let fault = FaultPlan::EveryNthLane { every: 1, at_index: 0 };
    let mut c_off = cfg(VerifyPolicyKind::Stall);
    c_off.fault = fault;
    let (off, _, off_m) = run(&mut rt, c_off, &reqs);
    assert!(off_m.rollbacks > 0, "fault injection must force rollbacks");
    assert_eq!(off, clean);

    let mut c_on = cfg(VerifyPolicyKind::MarginGate);
    c_on.fault = fault;
    let (on, _, on_m) = run(&mut rt, c_on, &reqs);
    assert_eq!(on, clean, "certified prefixes must never be retracted");
    assert!(
        on_m.verified_tokens > 0,
        "uncertified spans must still replay through windows"
    );
    // every rollback discarded speculative tokens only: the recomputed
    // count can never exceed what was decoded beyond the committed total
    assert!(on_m.recomputed_tokens <= on_m.decoded_tokens);
}

/// A corrupted (too-loose) `margin_bound` certifies tokens whose margin
/// does not actually clear the schedule-perturbation bound. The debug
/// replay assertion re-derives every certified token on the invariant
/// graph and must catch the first disagreement — and if no token happens
/// to disagree, the streams are by definition still bitwise identical.
/// Debug builds only: release builds skip the replay (the calibrated
/// bound is the production guarantee).
#[cfg(debug_assertions)]
#[test]
fn corrupted_margin_bound_is_caught_by_the_debug_replay() {
    use std::panic::{catch_unwind, AssertUnwindSafe};

    let mut rt = Runtime::load(artifacts_dir()).unwrap();
    let reqs = det_workload();
    let (reference, _, _) = run(&mut rt, cfg(VerifyPolicyKind::Stall), &reqs);

    let mut c = cfg(VerifyPolicyKind::MarginGate);
    // tiny positive bound: nearly every row "certifies", including rows
    // whose fast-path argmax genuinely flips under the invariant schedule
    // (0.0 would be rejected by Engine::new's calibration check)
    c.margin_bound_override = Some(1e-9);
    let result = catch_unwind(AssertUnwindSafe(|| run(&mut rt, c, &reqs)));
    match result {
        Err(payload) => {
            let msg = payload
                .downcast_ref::<String>()
                .cloned()
                .or_else(|| payload.downcast_ref::<&str>().map(|s| s.to_string()))
                .unwrap_or_default();
            assert!(
                msg.contains("margin certificate violated"),
                "expected the certificate-replay assertion, got: {msg}"
            );
        }
        Ok((streams, _, m)) => {
            // no certified token happened to flip: the gate must then have
            // been genuinely harmless
            assert_eq!(streams, reference);
            assert!(m.certified_tokens > 0, "a 1e-9 bound must certify");
        }
    }
}

#[test]
fn infinite_bound_certifies_nothing_and_changes_nothing() {
    // the adversarial-traffic configuration used by the benchmark: with
    // an infinite bound no row certifies, so the gate degrades to the
    // stall trigger exactly (modulo the O(vocab) margin scan)
    let mut rt = Runtime::load(artifacts_dir()).unwrap();
    let reqs = det_workload();
    let (base, base_digest, base_m) =
        run(&mut rt, cfg(VerifyPolicyKind::Stall), &reqs);
    let mut c = cfg(VerifyPolicyKind::MarginGate);
    c.margin_bound_override = Some(f32::INFINITY);
    let (streams, digest, m) = run(&mut rt, c, &reqs);
    assert_eq!(streams, base);
    assert_eq!(digest, base_digest);
    assert_eq!(m.certified_tokens, 0);
    assert_eq!(m.gate_repair_tokens, 0);
    assert_eq!(m.verify_passes, base_m.verify_passes);
    assert_eq!(m.forward_passes, base_m.forward_passes);
}

#[test]
fn gate_rejects_uncalibrated_artifacts() {
    // a NaN override stands in for a pre-calibration manifest: the gate
    // must refuse to start instead of silently certifying nothing (or
    // worse, everything)
    let mut rt = Runtime::load(artifacts_dir()).unwrap();
    let mut c = cfg(VerifyPolicyKind::MarginGate);
    c.margin_bound_override = Some(f32::NAN);
    assert!(Engine::new(&mut rt, c).is_err());
    let mut c = cfg(VerifyPolicyKind::MarginGate);
    c.margin_bound_override = Some(-1.0);
    assert!(Engine::new(&mut rt, c).is_err());
    // the stall trigger doesn't care: the bound is never consulted
    let mut c = cfg(VerifyPolicyKind::Stall);
    c.margin_bound_override = Some(f32::NAN);
    assert!(Engine::new(&mut rt, c).is_ok());
}
