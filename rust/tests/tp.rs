//! Cross-TP-degree determinism tests: the tensor-parallel rank count is
//! a deployment shape, not part of the reproducible configuration — with
//! a position-invariant collective (tree / multimem), committed streams
//! and engine digests are bitwise identical at R = 1, 2, 4 for every
//! scheduler policy x prefix-cache x fusion x verify-policy combination,
//! including under forced-mismatch rollbacks. The ring collective's
//! reduction grouping depends on R, so it demonstrably breaks the
//! contract (pinned here as a negative test).
//!
//! Self-bootstraps one sharded `test`-preset artifact set per (R,
//! collective) point via `aot::ensure_tp`.

use llm42::engine::{
    Engine, EngineConfig, FaultPlan, Mode, PolicyKind, Request, VerifyPolicy,
    VerifyPolicyKind,
};
use llm42::obs::digest_hex;
use llm42::prelude::*;

/// Artifact dir for one (degree, collective) point, generated on demand.
/// Distinct from the plain `artifacts` dir so non-TP tests never race it.
fn tp_dir(degree: usize, collective: &str) -> String {
    let base =
        std::env::var("LLM42_ARTIFACTS").unwrap_or_else(|_| "artifacts".into());
    let dir = format!("{base}-tp{degree}-{collective}");
    llm42::aot::ensure_tp(&dir, degree, collective)
        .expect("TP artifact generation failed");
    dir
}

/// Mixed workload: shared 32-token prefix (two full KV blocks so the
/// prefix cache genuinely adopts pages), det and nondet lanes, one greedy.
fn workload() -> Vec<Request> {
    let shared: Vec<u32> = (100..132).collect();
    let mk = |extra: u32, n: usize, det: bool, seed: u64| {
        let mut prompt = shared.clone();
        prompt.extend(extra..extra + 4);
        Request {
            prompt,
            max_new_tokens: n,
            deterministic: det,
            temperature: 1.0,
            seed,
            ..Default::default()
        }
    };
    vec![
        mk(200, 20, true, 11),
        mk(210, 16, true, 12),
        mk(220, 12, false, 13),
        Request {
            prompt: (10..22).collect(),
            max_new_tokens: 18,
            deterministic: true,
            temperature: 0.0,
            seed: 0,
            ..Default::default()
        },
    ]
}

/// Run the workload to completion under one configuration; return every
/// committed stream (sorted by id), the rollback count, and the engine
/// digest — the three things that must be R-invisible.
fn run_matrix(
    rt: &mut Runtime,
    policy: PolicyKind,
    cache: bool,
    fusion: bool,
    vp: VerifyPolicyKind,
    fault: FaultPlan,
) -> (Vec<(u64, Vec<u32>)>, u64, String) {
    let c = EngineConfig {
        mode: Mode::Llm42,
        verify_group: 2,
        verify_window: 16,
        max_stall_steps: 4,
        policy,
        prefix_cache: cache,
        max_step_tokens: if fusion { 48 } else { 0 },
        verify_policy: VerifyPolicy { kind: vp, ..Default::default() },
        fault,
        ..Default::default()
    };
    let mut eng = Engine::new(rt, c).unwrap();
    for r in workload() {
        eng.submit(r).unwrap();
    }
    eng.run_to_completion().unwrap();
    let rollbacks = eng.metrics.rollbacks;
    let digest = digest_hex(eng.obs.engine_digest());
    let mut outs: Vec<(u64, Vec<u32>)> = eng
        .take_finished()
        .into_iter()
        .map(|o| (o.id, o.tokens))
        .collect();
    outs.sort();
    (outs, rollbacks, digest)
}

#[test]
fn committed_streams_are_bitwise_identical_across_tp_degrees() {
    // The acceptance matrix: R in {1, 2, 4} x {tree, multimem} x all
    // three policies x cache on/off x fusion on/off x all three verify
    // policies. Every stream — deterministic and not — and the engine
    // digest must match the R=1 run bitwise: the canonical 8-shard
    // partial grid feeds a position-invariant combine the same floats in
    // the same order at every rank count.
    for collective in ["tree", "multimem"] {
        let mut base_rt = Runtime::load(tp_dir(1, collective)).unwrap();
        assert_eq!(base_rt.tp_degree(), 1);
        assert_eq!(base_rt.tp_collective(), collective);
        for degree in [2usize, 4] {
            let mut rt = Runtime::load(tp_dir(degree, collective)).unwrap();
            assert_eq!(rt.tp_degree(), degree);
            for policy in [
                PolicyKind::PrefillFirst,
                PolicyKind::DeadlineAware,
                PolicyKind::FairShare,
            ] {
                for cache in [false, true] {
                    for fusion in [false, true] {
                        for vp in [
                            VerifyPolicyKind::Stall,
                            VerifyPolicyKind::Slack,
                            VerifyPolicyKind::MarginGate,
                        ] {
                            let base = run_matrix(
                                &mut base_rt,
                                policy,
                                cache,
                                fusion,
                                vp,
                                FaultPlan::None,
                            );
                            assert_eq!(base.0.len(), 4);
                            assert!(base.0.iter().all(|(_, t)| !t.is_empty()));
                            let got = run_matrix(
                                &mut rt,
                                policy,
                                cache,
                                fusion,
                                vp,
                                FaultPlan::None,
                            );
                            assert_eq!(
                                base, got,
                                "{collective} R={degree} {policy:?} \
                                 cache={cache} fusion={fusion} {vp:?}: \
                                 diverged from R=1"
                            );
                        }
                    }
                }
            }
        }
    }
}

#[test]
fn forced_rollbacks_are_tp_degree_invariant() {
    // Fault injection forces a verifier mismatch on every verify lane —
    // maximum rollback/recompute pressure. The verify windows replay the
    // same sharded combine schedule the fast path used, so rollback
    // counts and post-rollback streams are R-invisible too.
    let fault = FaultPlan::EveryNthLane { every: 1, at_index: 0 };
    for collective in ["tree", "multimem"] {
        let mut base_rt = Runtime::load(tp_dir(1, collective)).unwrap();
        for fusion in [false, true] {
            let base = run_matrix(
                &mut base_rt,
                PolicyKind::PrefillFirst,
                false,
                fusion,
                VerifyPolicyKind::Stall,
                fault,
            );
            assert!(
                base.1 > 0,
                "{collective} fusion={fusion}: fault must force rollbacks"
            );
            for degree in [2usize, 4] {
                let mut rt =
                    Runtime::load(tp_dir(degree, collective)).unwrap();
                let got = run_matrix(
                    &mut rt,
                    PolicyKind::PrefillFirst,
                    false,
                    fusion,
                    VerifyPolicyKind::Stall,
                    fault,
                );
                assert_eq!(
                    base, got,
                    "{collective} R={degree} fusion={fusion}: \
                     rollback story diverged from R=1"
                );
            }
        }
    }
}

/// Prefill one position-sensitive prompt through a window graph and
/// return the raw logits bits of the last row.
fn window_logit_bits(rt: &mut Runtime) -> Vec<u32> {
    rt.reset_state().unwrap();
    let prompt: Vec<i32> = (0..32).map(|i| 7 + (i * 13) % 256).collect();
    rt.forward("window_inv_g1_t32", &prompt, &[0], &[0]).unwrap();
    rt.extract_logits(1)
        .unwrap()
        .iter()
        .map(|v| v.to_bits())
        .collect()
}

#[test]
fn ring_collective_breaks_cross_tp_invariance() {
    // The negative pin (paper Table 2): ring's reduce-scatter folds each
    // rank's local shard run first and then walks the ring from a
    // chunk-dependent start, so its reduction *grouping* changes with R.
    // At R=1 it degenerates to the in-order fold; at R=2 the same window
    // forward must produce different logit bits somewhere. Tree on the
    // same workload is the positive control.
    let mut ring1 = Runtime::load(tp_dir(1, "ring")).unwrap();
    let mut ring2 = Runtime::load(tp_dir(2, "ring")).unwrap();
    let bits1 = window_logit_bits(&mut ring1);
    let bits2 = window_logit_bits(&mut ring2);
    assert_eq!(bits1.len(), bits2.len());
    assert_ne!(
        bits1, bits2,
        "ring R=2 must diverge bitwise from R=1 on a position-sensitive \
         prefill (if this ever passes, the ring model stopped being \
         R-dependent and Table 2 needs revisiting)"
    );

    let mut tree1 = Runtime::load(tp_dir(1, "tree")).unwrap();
    let mut tree2 = Runtime::load(tp_dir(2, "tree")).unwrap();
    assert_eq!(
        window_logit_bits(&mut tree1),
        window_logit_bits(&mut tree2),
        "control: tree must be bitwise R-invariant on the same workload"
    );
}

#[test]
fn engine_asserts_tp_config_against_the_artifact_set() {
    // Like block_size, --tp / --collective are startup assertions against
    // the loaded artifact set's baked-in shard geometry.
    let mut rt = Runtime::load(tp_dir(2, "tree")).unwrap();
    let ok = EngineConfig {
        mode: Mode::Llm42,
        verify_group: 2,
        verify_window: 16,
        tp_degree: 2,
        collective: "tree".into(),
        ..Default::default()
    };
    assert!(Engine::new(&mut rt, ok).is_ok());
    let wrong_degree = EngineConfig {
        mode: Mode::Llm42,
        verify_group: 2,
        verify_window: 16,
        tp_degree: 4,
        ..Default::default()
    };
    assert!(Engine::new(&mut rt, wrong_degree).is_err());
    let wrong_collective = EngineConfig {
        mode: Mode::Llm42,
        verify_group: 2,
        verify_window: 16,
        collective: "multimem".into(),
        ..Default::default()
    };
    assert!(Engine::new(&mut rt, wrong_collective).is_err());
}

#[test]
fn tp_metrics_reach_the_stats_surface() {
    // The engine samples allreduce deltas per step (the overhead signal
    // the bench layer charts) and reports the degree gauge.
    let mut rt = Runtime::load(tp_dir(2, "tree")).unwrap();
    let (streams, _, _) = {
        let c = EngineConfig {
            mode: Mode::Llm42,
            verify_group: 2,
            verify_window: 16,
            max_stall_steps: 4,
            ..Default::default()
        };
        let mut eng = Engine::new(&mut rt, c).unwrap();
        for r in workload() {
            eng.submit(r).unwrap();
        }
        eng.run_to_completion().unwrap();
        assert_eq!(eng.metrics.tp_degree, 2);
        assert!(
            eng.metrics.tp_allreduces > 0,
            "sharded forwards must count allreduces"
        );
        let outs: Vec<(u64, Vec<u32>)> = eng
            .take_finished()
            .into_iter()
            .map(|o| (o.id, o.tokens))
            .collect();
        (outs, 0u64, String::new())
    };
    assert_eq!(streams.len(), 4);
}
