//! Id-stability tests for the slab-backed sequence store, at the engine
//! level: slot reuse must never let anything — scheduling policies or the
//! cancel path — reach a finished request's successor through a stale
//! address.
//!
//! * the cancel-then-recycle race: after a request is aborted and its
//!   store slot is reused, the old generational handle fails every
//!   lookup, and the old *request id* stays a cancel no-op (ids are never
//!   reused);
//! * scheduler plans referencing stale handles are rejected by the
//!   executor's validation (`check_plan` and the per-action checks), for
//!   every action kind that addresses a lane.
//!
//! The store's own unit tests (`engine/store.rs`) pin the same properties
//! at the data-structure level; these run them through a live engine.

use llm42::engine::scheduler::SchedulerPolicy;
use llm42::engine::{
    Action, Engine, EngineConfig, Mode, Request, SchedView, SeqId,
};
use llm42::prelude::*;

fn artifacts_dir() -> String {
    let dir = std::env::var("LLM42_ARTIFACTS").unwrap_or_else(|_| "artifacts".into());
    llm42::aot::ensure(&dir).expect("artifact generation failed");
    dir
}

fn cfg() -> EngineConfig {
    EngineConfig {
        mode: Mode::NonDeterministic,
        eos_token: 9999, // out of vocab: requests run their full budget
        ..Default::default()
    }
}

/// A policy that replays a captured (now stale) handle in the action kind
/// selected by `mode`. The executor must reject every one of them.
struct StaleReplay {
    stale: SeqId,
    mode: u8,
}

impl SchedulerPolicy for StaleReplay {
    fn name(&self) -> &'static str {
        "stale-replay"
    }

    fn plan(&mut self, _v: &SchedView) -> Action {
        match self.mode {
            0 => Action::Decode { lanes: vec![self.stale] },
            1 => Action::Prefill { seq: self.stale },
            2 => Action::Verify { lanes: vec![self.stale] },
            _ => Action::Preempt { victim: self.stale },
        }
    }
}

#[test]
fn recycled_slot_cannot_resurrect_a_cancelled_request() {
    let mut rt = Runtime::load(artifacts_dir()).unwrap();
    let mut eng = Engine::new(&mut rt, cfg()).unwrap();

    // A gets admitted and starts decoding; capture its handle
    let a = eng.submit(Request::greedy(vec![5; 6], 30, false)).unwrap();
    eng.step().unwrap();
    let a_sid = eng
        .view()
        .lanes
        .iter()
        .find(|l| l.id == a)
        .map(|l| l.sid)
        .expect("A is active after one step");

    // cancel A: its slot goes back to the free list
    assert!(eng.abort(a, FinishReason::Cancelled).unwrap());

    // B reuses A's slot — under a new generation
    let b = eng.submit(Request::greedy(vec![6; 6], 30, false)).unwrap();
    eng.step().unwrap();
    let b_sid = eng
        .view()
        .lanes
        .iter()
        .find(|l| l.id == b)
        .map(|l| l.sid)
        .expect("B is active after one step");
    assert_eq!(b_sid.slot(), a_sid.slot(), "the free slot is recycled");
    assert_ne!(
        b_sid.generation(),
        a_sid.generation(),
        "a recycled slot carries a fresh generation"
    );

    // the cancel-then-recycle race: cancelling A's id again is a no-op —
    // it must not touch B, which now occupies A's old slot
    assert!(!eng.abort(a, FinishReason::Cancelled).unwrap());
    assert!(
        eng.view().lanes.iter().any(|l| l.id == b),
        "B survives a replayed cancel of its slot's previous occupant"
    );
}

#[test]
fn plans_with_stale_handles_are_rejected_for_every_action_kind() {
    let mut rt = Runtime::load(artifacts_dir()).unwrap();
    for mode in 0..4u8 {
        let mut eng = Engine::new(&mut rt, cfg()).unwrap();
        let a = eng.submit(Request::greedy(vec![5; 6], 30, false)).unwrap();
        eng.step().unwrap();
        let a_sid = eng
            .view()
            .lanes
            .iter()
            .find(|l| l.id == a)
            .map(|l| l.sid)
            .expect("A is active");
        assert!(eng.abort(a, FinishReason::Cancelled).unwrap());
        // B occupies the recycled slot; the stale policy replays A's handle
        let b = eng.submit(Request::greedy(vec![6; 6], 30, false)).unwrap();
        eng.step().unwrap();
        eng.set_policy_boxed(Box::new(StaleReplay { stale: a_sid, mode }));
        assert!(
            eng.step().is_err(),
            "mode {mode}: a stale handle must fail validation, not drive \
             the slot's new occupant"
        );
        // the failed step mutated nothing: B is still live and intact
        assert!(eng.view().lanes.iter().any(|l| l.id == b));
    }
}

#[test]
fn store_gauges_reach_the_stats_surface() {
    // live_seqs / live_seqs_hwm / store_capacity flow store -> metrics
    let mut rt = Runtime::load(artifacts_dir()).unwrap();
    let mut eng = Engine::new(&mut rt, cfg()).unwrap();
    let ids: Vec<u64> = (0..3)
        .map(|i| eng.submit(Request::greedy(vec![5 + i; 4], 4, false)).unwrap())
        .collect();
    assert_eq!(eng.metrics.live_seqs, 3);
    eng.run_to_completion().unwrap();
    assert_eq!(eng.take_finished().len(), ids.len());
    assert_eq!(eng.metrics.live_seqs, 0, "drained engine holds nothing live");
    assert_eq!(eng.metrics.live_seqs_hwm, 3);
    assert!(
        eng.metrics.store_capacity <= eng.metrics.live_seqs_hwm,
        "slab capacity is bounded by the live high-water mark"
    );
}
