//! Step-composer tests: fused token-budgeted steps must change *when*
//! work runs, never *what* deterministic requests commit.
//!
//! * committed streams of deterministic requests are bitwise identical
//!   with fusion on vs off, across all three policies, prefix cache on
//!   and off — including under forced-mismatch rollback inside fused
//!   steps;
//! * batch-invariant mode is bitwise fusion-invariant for *every* stream
//!   (the fused graph carries the same universal schedule);
//! * fusion strictly reduces forwards per committed token on a
//!   prefill-heavy mixed workload (the headline perf criterion);
//! * `BatchPlan` validation rejects overlapping lanes, budget overruns,
//!   and prefill of non-prefilling sequences (pure property test plus
//!   live-executor rejection via a malicious policy).

use llm42::engine::scheduler::SchedulerPolicy;
use llm42::engine::sequence::Phase;
use llm42::engine::{
    Action, BatchPlan, Engine, EngineConfig, FaultPlan, LaneView, Mode,
    PolicyKind, Request, SchedView, SeqId,
};
use llm42::prelude::*;
use llm42::util::rng::SplitMix64;

/// Synthetic-view handle: slot = i, generation 0.
fn sid(i: usize) -> SeqId {
    SeqId::from_parts(i as u32, 0)
}

fn artifacts_dir() -> String {
    let dir = std::env::var("LLM42_ARTIFACTS").unwrap_or_else(|_| "artifacts".into());
    llm42::aot::ensure(&dir).expect("artifact generation failed");
    dir
}

fn cfg(mode: Mode, budget: usize) -> EngineConfig {
    EngineConfig {
        mode,
        verify_group: 2,
        verify_window: 16,
        max_stall_steps: 4,
        max_step_tokens: budget,
        ..Default::default()
    }
}

/// Prefix-heavy mixed workload: deterministic and non-deterministic
/// requests sharing a long common prompt prefix (cache-relevant), long
/// enough prompts that fused steps genuinely mix prefill with decode.
fn workload() -> Vec<Request> {
    let shared: Vec<u32> = (100..148).collect(); // 48 tokens = 3 blocks
    (0..5u64)
        .map(|i| {
            let mut prompt = shared.clone();
            prompt.extend((200 + 3 * i as u32)..(200 + 3 * i as u32 + 4));
            Request {
                prompt,
                max_new_tokens: 12 + i as usize,
                deterministic: i < 3,
                temperature: 1.0,
                seed: 7 + i,
                priority: (i % 3) as u8,
                deadline_ms: if i == 1 { Some(400.0) } else { None },
                ..Default::default()
            }
        })
        .collect()
}

/// Run the workload; returns (det streams sorted by id, fused step count,
/// forward passes, committed tokens).
fn run_workload(
    rt: &mut Runtime,
    policy: PolicyKind,
    cache: bool,
    budget: usize,
    fault: FaultPlan,
) -> (Vec<(u64, Vec<u32>)>, u64, u64, u64) {
    let mut c = cfg(Mode::Llm42, budget);
    c.policy = policy;
    c.prefix_cache = cache;
    c.fault = fault;
    let mut eng = Engine::new(rt, c).unwrap();
    let all = workload();
    // the first request lands alone and prefills the shared prefix
    // (publishing its blocks when the cache is on); the rest arrive a
    // fixed three steps later — the same arrival schedule in every run
    eng.submit(all[0].clone()).unwrap();
    for _ in 0..3 {
        eng.step().unwrap();
    }
    for r in &all[1..] {
        eng.submit(r.clone()).unwrap();
    }
    eng.run_to_completion().unwrap();
    let outs = eng.take_finished();
    assert_eq!(outs.len(), all.len(), "every request finishes");
    let mut det: Vec<(u64, Vec<u32>)> = outs
        .iter()
        .filter(|o| o.deterministic)
        .map(|o| (o.id, o.tokens.clone()))
        .collect();
    det.sort();
    (
        det,
        eng.metrics.fused_steps,
        eng.metrics.forward_passes,
        eng.metrics.committed_tokens,
    )
}

#[test]
fn fused_steps_preserve_deterministic_streams_across_policies_and_cache() {
    let mut rt = Runtime::load(artifacts_dir()).unwrap();
    for policy in [
        PolicyKind::PrefillFirst,
        PolicyKind::DeadlineAware,
        PolicyKind::FairShare,
    ] {
        for cache in [false, true] {
            let (serial, fused_serial, _, _) =
                run_workload(&mut rt, policy, cache, 0, FaultPlan::None);
            let (fused, fused_steps, _, _) =
                run_workload(&mut rt, policy, cache, 48, FaultPlan::None);
            assert_eq!(fused_serial, 0, "{policy:?}: budget 0 must not fuse");
            assert!(
                fused_steps > 0,
                "{policy:?} cache={cache}: the workload must exercise fused steps"
            );
            assert_eq!(
                serial, fused,
                "{policy:?} cache={cache}: deterministic streams must be \
                 bitwise identical fused-on vs fused-off"
            );
        }
    }
}

#[test]
fn forced_mismatch_rollback_under_fused_steps_matches_serial() {
    // maximum rollback pressure: every verify lane reports a mismatch at
    // window position 0 — committed streams are the verifier's replay
    // sequence in both runs, so fusion must not change a single bit, even
    // when the rolled-back window overlaps shared/published prefix pages
    // (the cache-on arm exercises the COW path inside fused steps)
    let mut rt = Runtime::load(artifacts_dir()).unwrap();
    let fault = FaultPlan::EveryNthLane { every: 1, at_index: 0 };
    for policy in [
        PolicyKind::PrefillFirst,
        PolicyKind::DeadlineAware,
        PolicyKind::FairShare,
    ] {
        for cache in [false, true] {
            let (serial, _, _, _) = run_workload(&mut rt, policy, cache, 0, fault);
            let (fused, fused_steps, _, _) =
                run_workload(&mut rt, policy, cache, 48, fault);
            assert!(fused_steps > 0);
            assert_eq!(
                serial, fused,
                "{policy:?} cache={cache}: rollback under a fused step must \
                 replay identically"
            );
        }
    }
}

#[test]
fn batch_invariant_mode_is_bitwise_fusion_invariant_for_every_stream() {
    // In batch-invariant mode every committed token comes from the
    // universal schedule — and the fused graph carries exactly that
    // schedule with lane-independent rows, so fusion must be bitwise
    // invisible for *all* traffic, not just deterministic requests.
    let mut rt = Runtime::load(artifacts_dir()).unwrap();
    let mut run = |rt: &mut Runtime, budget: usize| -> Vec<(u64, Vec<u32>)> {
        let mut eng = Engine::new(rt, cfg(Mode::BatchInvariant, budget)).unwrap();
        for r in workload() {
            eng.submit(r).unwrap();
        }
        eng.run_to_completion().unwrap();
        let mut outs: Vec<(u64, Vec<u32>)> = eng
            .take_finished()
            .into_iter()
            .map(|o| (o.id, o.tokens))
            .collect();
        outs.sort();
        outs
    };
    let serial = run(&mut rt, 0);
    let fused = run(&mut rt, 64);
    assert_eq!(serial, fused);
}

#[test]
fn fusion_cuts_forwards_per_committed_token_on_prefill_heavy_traffic() {
    // The headline perf criterion: >= 25% fewer forwards per committed
    // token with fusion on vs off at equal max_batch. Long prompts +
    // short outputs is the shape where exclusive prefill steps starve the
    // decode lanes. eos is out of vocab so both runs commit exactly
    // n * max_new tokens and the ratio comparison is exact.
    let mut rt = Runtime::load(artifacts_dir()).unwrap();
    let reqs: Vec<Request> = (0..10u64)
        .map(|i| Request {
            prompt: (0..100).map(|p| 3 + ((p + i as u32 * 17) % 300)).collect(),
            max_new_tokens: 8,
            deterministic: false,
            temperature: 0.0,
            seed: 0,
            ..Default::default()
        })
        .collect();
    let mut run = |rt: &mut Runtime, budget: usize| -> (u64, u64) {
        let mut c = cfg(Mode::Llm42, budget);
        c.eos_token = 9999;
        let mut eng = Engine::new(rt, c).unwrap();
        for r in &reqs {
            eng.submit(r.clone()).unwrap();
        }
        eng.run_to_completion().unwrap();
        assert_eq!(eng.take_finished().len(), reqs.len());
        (eng.metrics.forward_passes, eng.metrics.committed_tokens)
    };
    let (serial_fwd, serial_tok) = run(&mut rt, 0);
    let (fused_fwd, fused_tok) = run(&mut rt, 128);
    assert_eq!(serial_tok, fused_tok, "identical committed volume");
    assert_eq!(serial_tok, 10 * 8);
    let serial_ratio = serial_fwd as f64 / serial_tok as f64;
    let fused_ratio = fused_fwd as f64 / fused_tok as f64;
    assert!(
        fused_ratio <= 0.75 * serial_ratio,
        "fusion must cut forwards/token by >= 25%: serial {serial_ratio:.3} \
         ({serial_fwd} forwards), fused {fused_ratio:.3} ({fused_fwd} forwards)"
    );
}

// ---------------------------------------------------------------- plans

fn lane(idx: usize, phase: Phase, can_decode: bool, verify_ready: bool) -> LaneView {
    LaneView {
        sid: sid(idx),
        id: idx as u64 + 1,
        phase,
        deterministic: true,
        priority: 0,
        deadline_ms: None,
        timeout_ms: None,
        arrive_time: idx as f64,
        prompt_len: 24,
        prefill_pos: if phase == Phase::Prefilling { 4 } else { 24 },
        committed: if phase == Phase::Prefilling { 0 } else { 1 },
        speculative: 0,
        max_new_tokens: 32,
        stall_steps: 0,
        preemptions: 0,
        kv_blocks: 1,
        can_decode,
        verify_ready,
        decoding_done: false,
    }
}

#[test]
fn batch_plan_validation_property() {
    // seeded sweep: a plan built from eligible lanes within the budget
    // always validates; targeted corruptions — overlapping lanes, budget
    // overruns, prefill of non-prefilling sequences, oversized or zero
    // chunks — always fail
    let mut rng = SplitMix64::new(4242);
    for case in 0..200 {
        let n_pre = 1 + rng.below(3) as usize;
        let n_dec = rng.below(4) as usize;
        let n_rdy = rng.below(3) as usize;
        let mut lanes = Vec::new();
        let mut idx = 0usize;
        for _ in 0..n_pre {
            lanes.push(lane(idx, Phase::Prefilling, false, false));
            idx += 1;
        }
        for _ in 0..n_dec {
            lanes.push(lane(idx, Phase::Decoding, true, false));
            idx += 1;
        }
        for _ in 0..n_rdy {
            let mut l = lane(idx, Phase::Decoding, false, true);
            l.speculative = 15;
            lanes.push(l);
            idx += 1;
        }
        let budget = 4 + rng.below(40) as usize;
        let v = SchedView {
            now: 100.0,
            dvr: true,
            verify_group: 2,
            verify_window: 16,
            max_stall_steps: 4,
            max_batch: 8,
            max_step_tokens: budget,
            free_slots: 0,
            free_blocks: 8,
            cached_blocks: 0,
            prefix_cache: false,
            verify_policy: Default::default(),
            lanes,
            queue: vec![],
        };

        // a well-formed plan: decode lanes first, then prefill chunks
        // packed into the remaining budget, verify riding along
        let mut plan = BatchPlan::default();
        for l in v.lanes.iter().filter(|l| l.can_decode) {
            if plan.fast_tokens() < budget {
                plan.decode.push(l.sid);
            }
        }
        let mut left = budget - plan.fast_tokens();
        for l in v.lanes.iter().filter(|l| l.phase == Phase::Prefilling) {
            if left == 0 {
                break;
            }
            let chunk = l.prefill_remaining().min(left);
            assert!(chunk > 0, "prefilling lanes have work");
            plan.prefill.push((l.sid, chunk));
            left -= chunk;
        }
        plan.verify = v
            .lanes
            .iter()
            .filter(|l| l.verify_ready)
            .map(|l| l.sid)
            .take(v.verify_group)
            .collect();
        assert!(plan.validate(&v).is_ok(), "case {case}: {plan:?}");

        // corruption 1: one lane in two phases
        if let Some(&d) = plan.decode.first() {
            let mut bad = plan.clone();
            bad.verify = vec![d];
            assert!(bad.validate(&v).is_err(), "case {case}: overlap accepted");
        }
        // corruption 2: budget overrun via an oversized-but-real chunk
        {
            let mut bad = plan.clone();
            let pre_sid = v
                .lanes
                .iter()
                .find(|l| l.phase == Phase::Prefilling)
                .unwrap()
                .sid;
            bad.prefill = vec![(pre_sid, budget + 1)];
            bad.decode.clear();
            // either the chunk exceeds the budget or the lane's remaining
            // tokens — both must be rejected
            assert!(bad.validate(&v).is_err(), "case {case}: overrun accepted");
        }
        // corruption 3: prefill of a non-prefilling lane
        if let Some(l) = v.lanes.iter().find(|l| l.phase == Phase::Decoding) {
            let mut bad = plan.clone();
            bad.prefill = vec![(l.sid, 1)];
            bad.decode.retain(|&s| s != l.sid);
            bad.verify.retain(|&s| s != l.sid);
            assert!(
                bad.validate(&v).is_err(),
                "case {case}: non-prefilling prefill accepted"
            );
        }
        // corruption 4: zero-length chunk
        {
            let mut bad = plan.clone();
            let pre_sid = bad.prefill.first().map(|&(s, _)| s).unwrap_or_else(|| {
                v.lanes
                    .iter()
                    .find(|l| l.phase == Phase::Prefilling)
                    .unwrap()
                    .sid
            });
            bad.prefill = vec![(pre_sid, 0)];
            assert!(bad.validate(&v).is_err(), "case {case}: zero chunk accepted");
        }
        // corruption 5: a stale generational handle (matches no lane)
        {
            let mut bad = plan.clone();
            bad.decode = vec![SeqId::from_parts(0, u32::MAX)];
            assert!(bad.validate(&v).is_err(), "case {case}: stale handle accepted");
        }
    }
}

/// A policy that admits, then emits one malformed plan (selected by
/// `mode`) — the executor must reject it loudly instead of corrupting
/// state.
struct EvilPolicy {
    mode: u8,
}

impl SchedulerPolicy for EvilPolicy {
    fn name(&self) -> &'static str {
        "evil"
    }

    fn plan(&mut self, v: &SchedView) -> Action {
        if !v.queue.is_empty() && v.free_slots > 0 {
            return Action::Admit { n: 1 };
        }
        let sid = v.lanes[0].sid;
        match self.mode {
            // oversized chunk (beyond both the budget and the remaining)
            0 => Action::Run(BatchPlan {
                prefill: vec![(sid, 10_000)],
                ..Default::default()
            }),
            // duplicate lane within one phase
            1 => Action::Run(BatchPlan {
                prefill: vec![(sid, 1), (sid, 1)],
                ..Default::default()
            }),
            // verify of a lane that is not verify-ready
            2 => Action::Run(BatchPlan {
                verify: vec![sid],
                ..Default::default()
            }),
            // a stale generational handle: the lane's slot with a
            // generation that was never issued — the executor must treat
            // it exactly like an unknown lane
            3 => Action::Run(BatchPlan {
                decode: vec![SeqId::from_parts(sid.slot() as u32, sid.generation().wrapping_add(40))],
                ..Default::default()
            }),
            // empty plan
            _ => Action::Run(BatchPlan::default()),
        }
    }
}

#[test]
fn executor_rejects_malformed_plans() {
    let mut rt = Runtime::load(artifacts_dir()).unwrap();
    for mode in 0..5u8 {
        let mut eng = Engine::new(&mut rt, cfg(Mode::Llm42, 32)).unwrap();
        eng.set_policy_boxed(Box::new(EvilPolicy { mode }));
        eng.submit(Request::greedy((10..42).collect(), 4, true)).unwrap();
        let mut rejected = false;
        for _ in 0..4 {
            if eng.step().is_err() {
                rejected = true;
                break;
            }
        }
        assert!(rejected, "mode {mode}: malformed plan must be rejected");
    }
}

#[test]
fn run_action_rejected_when_fusion_disabled() {
    // Action::Run is only legal under a token budget; with the composer
    // off the executor refuses it even if the plan itself is well-formed
    let mut rt = Runtime::load(artifacts_dir()).unwrap();
    struct RunAnyway;
    impl SchedulerPolicy for RunAnyway {
        fn name(&self) -> &'static str {
            "run-anyway"
        }
        fn plan(&mut self, v: &SchedView) -> Action {
            if !v.queue.is_empty() && v.free_slots > 0 {
                return Action::Admit { n: 1 };
            }
            Action::Run(BatchPlan {
                prefill: vec![(v.lanes[0].sid, 1)],
                ..Default::default()
            })
        }
    }
    let mut eng = Engine::new(&mut rt, cfg(Mode::Llm42, 0)).unwrap();
    eng.set_policy_boxed(Box::new(RunAnyway));
    eng.submit(Request::greedy((10..42).collect(), 4, true)).unwrap();
    let mut rejected = false;
    for _ in 0..4 {
        if eng.step().is_err() {
            rejected = true;
            break;
        }
    }
    assert!(rejected);
}
