//! Integration tests for the paper's headline guarantee: a request with
//! `is_deterministic = true` produces a bitwise-identical token stream on
//! every run, regardless of co-traffic, while the fast path alone does not.
//!
//! Requires `make artifacts` (the tiny-preset artifact set). Each test fn
//! owns a PJRT client; assertions are grouped to amortize XLA compilation.

use llm42::engine::{Engine, EngineConfig, FaultPlan, Mode, PolicyKind, Request};
use llm42::prelude::*;

fn artifacts_dir() -> String {
    let dir = std::env::var("LLM42_ARTIFACTS").unwrap_or_else(|_| "artifacts".into());
    llm42::aot::ensure(&dir).expect("artifact generation failed");
    dir
}

fn cfg(mode: Mode) -> EngineConfig {
    EngineConfig {
        mode,
        verify_group: 2,
        verify_window: 16,
        max_stall_steps: 4,
        ..Default::default()
    }
}

fn det_request(seed: u64) -> Request {
    Request {
        prompt: (10..26).collect(),
        max_new_tokens: 40,
        deterministic: true,
        temperature: 1.0,
        seed,
        ..Default::default()
    }
}

fn co_request(seed: u64, len: usize) -> Request {
    Request {
        prompt: (30..30 + 12).collect(),
        max_new_tokens: len,
        deterministic: false,
        temperature: 1.0,
        seed,
        ..Default::default()
    }
}

/// Run one deterministic request in llm42 mode surrounded by arbitrary
/// co-traffic; return its committed tokens (and its fast trace).
fn run_with_cotraffic(
    rt: &mut Runtime,
    mode: Mode,
    co: &[Request],
    fault: FaultPlan,
) -> (Vec<u32>, Vec<u32>, u64, u64) {
    let mut c = cfg(mode);
    c.fault = fault;
    let mut eng = Engine::new(rt, c).unwrap();
    let det_id = eng.submit(det_request(7)).unwrap();
    for r in co {
        eng.submit(r.clone()).unwrap();
    }
    eng.run_to_completion().unwrap();
    let outs = eng.take_finished();
    let out = outs.iter().find(|o| o.id == det_id).unwrap();
    (
        out.tokens.clone(),
        out.fast_trace.clone(),
        out.metrics.rollbacks,
        out.metrics.recomputed_tokens,
    )
}

#[test]
fn deterministic_requests_are_bitwise_reproducible_across_cotraffic() {
    let mut rt = Runtime::load(artifacts_dir()).unwrap();

    // co-traffic patterns that force different bucket trajectories:
    // solo (bucket 1), two neighbors (bucket 4 ramps), three neighbors
    let patterns: Vec<Vec<Request>> = vec![
        vec![],
        vec![co_request(100, 48), co_request(101, 32)],
        vec![co_request(200, 16), co_request(201, 64), co_request(202, 40)],
    ];

    let mut streams = Vec::new();
    for pat in &patterns {
        let (tokens, _, _, _) =
            run_with_cotraffic(&mut rt, Mode::Llm42, pat, FaultPlan::None);
        assert!(!tokens.is_empty());
        streams.push(tokens);
    }
    // headline guarantee: identical committed output under every pattern
    assert_eq!(streams[0], streams[1], "solo vs 2-neighbor co-traffic");
    assert_eq!(streams[0], streams[2], "solo vs 3-neighbor co-traffic");

    // and re-running the same pattern is also identical (same-run control)
    let (again, _, _, _) =
        run_with_cotraffic(&mut rt, Mode::Llm42, &patterns[1], FaultPlan::None);
    assert_eq!(streams[0], again);
}

#[test]
fn fast_path_logits_diverge_across_bucket_trajectories() {
    // The mechanism (paper Fig. 3 / O1): the same token through different
    // batch buckets takes a different split-K reduction tree, so its
    // logits are bitwise different. Token-level flips are then a
    // *statistical* consequence measured by the Fig. 6 harness; here we
    // assert the deterministic part bitwise.
    let mut rt = Runtime::load(artifacts_dir()).unwrap();
    let vocab = rt.dims().vocab;
    let trash = (rt.dims().slots - 1) as i32;

    // same token, same slot 0, same position, as lane 0 of bucket 1 vs 4
    rt.reset_state().unwrap();
    rt.forward("decode_fast_b1", &[42], &[0], &[0]).unwrap();
    let l1 = rt.extract_logits(1).unwrap().to_vec();

    rt.reset_state().unwrap();
    rt.forward(
        "decode_fast_b4",
        &[42, 43, 44, 45],
        &[0, 1, 2, trash],
        &[0, 0, 0, 0],
    )
    .unwrap();
    let l4 = rt.extract_logits(4).unwrap().to_vec();

    let same_bits = l1[..vocab]
        .iter()
        .zip(&l4[..vocab])
        .all(|(a, b)| a.to_bits() == b.to_bits());
    assert!(
        !same_bits,
        "bucket-1 and bucket-4 schedules must produce different logits"
    );
    // ...but the drift is small: same argmax ordering magnitude-wise
    let max_diff = l1[..vocab]
        .iter()
        .zip(&l4[..vocab])
        .map(|(a, b)| (a - b).abs())
        .fold(0f32, f32::max);
    assert!(max_diff < 1.0, "drift should be perturbative, got {max_diff}");

    // per-schedule determinism (O2): re-running bucket 4 is bitwise equal
    rt.reset_state().unwrap();
    rt.forward(
        "decode_fast_b4",
        &[42, 43, 44, 45],
        &[0, 1, 2, trash],
        &[0, 0, 0, 0],
    )
    .unwrap();
    let l4b = rt.extract_logits(4).unwrap().to_vec();
    assert!(l4
        .iter()
        .zip(&l4b)
        .all(|(a, b)| a.to_bits() == b.to_bits()));

    // control at stream level: identical co-traffic -> identical stream
    let co = vec![co_request(300, 48)];
    let (a, _, _, _) =
        run_with_cotraffic(&mut rt, Mode::NonDeterministic, &co, FaultPlan::None);
    let (b, _, _, _) =
        run_with_cotraffic(&mut rt, Mode::NonDeterministic, &co, FaultPlan::None);
    assert_eq!(a, b);
}

#[test]
fn llm42_output_matches_batch_invariant_reference() {
    // Both enforce determinism; they must agree with THEMSELVES across
    // runs. (They need not agree with each other: the verifier's fixed
    // schedule and the batch-invariant schedule are different fixed
    // schedules — determinism is per-system, as in the paper.)
    let mut rt = Runtime::load(artifacts_dir()).unwrap();
    let (inv_a, _, _, _) =
        run_with_cotraffic(&mut rt, Mode::BatchInvariant, &[], FaultPlan::None);
    let co = vec![co_request(400, 32)];
    let (inv_b, _, _, _) =
        run_with_cotraffic(&mut rt, Mode::BatchInvariant, &co, FaultPlan::None);
    assert_eq!(inv_a, inv_b, "batch-invariant mode must be batch-insensitive");
}

#[test]
fn forced_rollbacks_preserve_output_and_forward_progress() {
    let mut rt = Runtime::load(artifacts_dir()).unwrap();

    let (clean, _, rb_clean, _) =
        run_with_cotraffic(&mut rt, Mode::Llm42, &[], FaultPlan::None);

    // fault injection: every verification lane reports a mismatch at the
    // first window position -> maximum rollback pressure
    let (faulted, _, rb_fault, recomputed) = run_with_cotraffic(
        &mut rt,
        Mode::Llm42,
        &[],
        FaultPlan::EveryNthLane { every: 1, at_index: 0 },
    );
    assert!(rb_fault > rb_clean, "fault injection must trigger rollbacks");
    assert!(recomputed > 0);
    // the committed stream still comes from the verifier's deterministic
    // replay, so the output is unchanged — rollbacks cost work, not truth
    assert_eq!(clean, faulted);
}

#[test]
fn eos_and_length_edges_respect_limits() {
    let mut rt = Runtime::load(artifacts_dir()).unwrap();
    let mut eng = Engine::new(&mut rt, cfg(Mode::Llm42)).unwrap();

    // max_new_tokens = 1: prefill commits the only token
    let id1 = eng
        .submit(Request {
            prompt: (10..20).collect(),
            max_new_tokens: 1,
            deterministic: true,
            temperature: 0.0,
            seed: 0,
            ..Default::default()
        })
        .unwrap();
    // a deterministic request that stops mid-window
    let id2 = eng
        .submit(Request {
            prompt: (40..56).collect(),
            max_new_tokens: 5,
            deterministic: true,
            temperature: 1.0,
            seed: 9,
            ..Default::default()
        })
        .unwrap();
    eng.run_to_completion().unwrap();
    let outs = eng.take_finished();
    let o1 = outs.iter().find(|o| o.id == id1).unwrap();
    let o2 = outs.iter().find(|o| o.id == id2).unwrap();
    assert_eq!(o1.tokens.len(), 1);
    assert!(o2.tokens.len() <= 5);
    assert!(!o2.tokens.is_empty());

    // oversized requests are rejected up front
    let too_big = Request {
        prompt: vec![5; 600],
        max_new_tokens: 100,
        deterministic: true,
        temperature: 0.0,
        seed: 0,
        ..Default::default()
    };
    assert!(eng.submit(too_big).is_err());
    // out-of-vocab prompt rejected
    let bad = Request {
        prompt: vec![1_000_000],
        max_new_tokens: 4,
        deterministic: false,
        temperature: 0.0,
        seed: 0,
        ..Default::default()
    };
    assert!(eng.submit(bad).is_err());
}

#[test]
fn every_policy_preserves_deterministic_streams_across_cotraffic() {
    // Acceptance criterion for the scheduler/executor split: under every
    // scheduling policy, Mode::Llm42 yields identical committed tokens for
    // deterministic requests across runs with *different* background
    // traffic — scheduling reorders work, never results. The backgrounds
    // differ in count, length, priority, and deadlines, so the deadline /
    // fair-share runs take genuinely different admission, verification,
    // and preemption paths.
    let mut rt = Runtime::load(artifacts_dir()).unwrap();

    let bg = |seed: u64, len: usize, priority: u8, deadline: Option<f64>| Request {
        prompt: (30..30 + 12).collect(),
        max_new_tokens: len,
        deterministic: false,
        temperature: 1.0,
        seed,
        priority,
        deadline_ms: deadline,
    };
    let backgrounds: Vec<Vec<Request>> = vec![
        vec![],
        vec![bg(500, 40, 0, None), bg(501, 24, 3, Some(400.0))],
        vec![
            bg(600, 16, 0, None),
            bg(601, 48, 2, Some(150.0)),
            bg(602, 32, 1, None),
            bg(603, 20, 3, Some(50.0)),
        ],
    ];

    for policy in [
        PolicyKind::PrefillFirst,
        PolicyKind::DeadlineAware,
        PolicyKind::FairShare,
    ] {
        let mut streams: Vec<Vec<u32>> = Vec::new();
        for pat in &backgrounds {
            let mut c = cfg(Mode::Llm42);
            c.policy = policy;
            let mut eng = Engine::new(&mut rt, c).unwrap();
            let mut det = det_request(7);
            det.priority = 2;
            det.deadline_ms = Some(800.0);
            let det_id = eng.submit(det).unwrap();
            for r in pat {
                eng.submit(r.clone()).unwrap();
            }
            eng.run_to_completion().unwrap();
            let outs = eng.take_finished();
            assert_eq!(outs.len(), pat.len() + 1, "{policy:?}: all requests finish");
            let out = outs.iter().find(|o| o.id == det_id).unwrap();
            assert!(!out.tokens.is_empty());
            streams.push(out.tokens.clone());
        }
        assert_eq!(streams[0], streams[1], "{policy:?}: bg pattern 1");
        assert_eq!(streams[0], streams[2], "{policy:?}: bg pattern 2");
    }
}

#[test]
fn greedy_zero_temperature_is_deterministic_even_without_dvr() {
    // a sanity baseline: greedy + identical batching reproduces exactly
    let mut rt = Runtime::load(artifacts_dir()).unwrap();
    let req = Request {
        prompt: (10..26).collect(),
        max_new_tokens: 24,
        deterministic: false,
        temperature: 0.0,
        seed: 0,
        ..Default::default()
    };
    let mut run = |rt: &mut Runtime| {
        let mut eng = Engine::new(rt, cfg(Mode::NonDeterministic)).unwrap();
        eng.submit(req.clone()).unwrap();
        eng.run_to_completion().unwrap();
        eng.take_finished().pop().unwrap().tokens
    };
    let a = run(&mut rt);
    let b = run(&mut rt);
    assert_eq!(a, b);
}
