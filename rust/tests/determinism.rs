//! Integration tests for the paper's headline guarantee: a request with
//! `is_deterministic = true` produces a bitwise-identical token stream on
//! every run, regardless of co-traffic, while the fast path alone does not.
//!
//! Requires `make artifacts` (the tiny-preset artifact set). Each test fn
//! owns a PJRT client; assertions are grouped to amortize XLA compilation.

use llm42::engine::{Engine, EngineConfig, FaultPlan, Mode, PolicyKind, Request};
use llm42::prelude::*;

fn artifacts_dir() -> String {
    let dir = std::env::var("LLM42_ARTIFACTS").unwrap_or_else(|_| "artifacts".into());
    llm42::aot::ensure(&dir).expect("artifact generation failed");
    dir
}

fn cfg(mode: Mode) -> EngineConfig {
    EngineConfig {
        mode,
        verify_group: 2,
        verify_window: 16,
        max_stall_steps: 4,
        ..Default::default()
    }
}

fn det_request(seed: u64) -> Request {
    Request {
        prompt: (10..26).collect(),
        max_new_tokens: 40,
        deterministic: true,
        temperature: 1.0,
        seed,
        ..Default::default()
    }
}

fn co_request(seed: u64, len: usize) -> Request {
    Request {
        prompt: (30..30 + 12).collect(),
        max_new_tokens: len,
        deterministic: false,
        temperature: 1.0,
        seed,
        ..Default::default()
    }
}

/// Run one deterministic request in llm42 mode surrounded by arbitrary
/// co-traffic; return its committed tokens (and its fast trace).
fn run_with_cotraffic(
    rt: &mut Runtime,
    mode: Mode,
    co: &[Request],
    fault: FaultPlan,
) -> (Vec<u32>, Vec<u32>, u64, u64) {
    let mut c = cfg(mode);
    c.fault = fault;
    let mut eng = Engine::new(rt, c).unwrap();
    let det_id = eng.submit(det_request(7)).unwrap();
    for r in co {
        eng.submit(r.clone()).unwrap();
    }
    eng.run_to_completion().unwrap();
    let outs = eng.take_finished();
    let out = outs.iter().find(|o| o.id == det_id).unwrap();
    (
        out.tokens.clone(),
        out.fast_trace.clone(),
        out.metrics.rollbacks,
        out.metrics.recomputed_tokens,
    )
}

#[test]
fn deterministic_requests_are_bitwise_reproducible_across_cotraffic() {
    let mut rt = Runtime::load(artifacts_dir()).unwrap();

    // co-traffic patterns that force different bucket trajectories:
    // solo (bucket 1), two neighbors (bucket 4 ramps), three neighbors
    let patterns: Vec<Vec<Request>> = vec![
        vec![],
        vec![co_request(100, 48), co_request(101, 32)],
        vec![co_request(200, 16), co_request(201, 64), co_request(202, 40)],
    ];

    let mut streams = Vec::new();
    for pat in &patterns {
        let (tokens, _, _, _) =
            run_with_cotraffic(&mut rt, Mode::Llm42, pat, FaultPlan::None);
        assert!(!tokens.is_empty());
        streams.push(tokens);
    }
    // headline guarantee: identical committed output under every pattern
    assert_eq!(streams[0], streams[1], "solo vs 2-neighbor co-traffic");
    assert_eq!(streams[0], streams[2], "solo vs 3-neighbor co-traffic");

    // and re-running the same pattern is also identical (same-run control)
    let (again, _, _, _) =
        run_with_cotraffic(&mut rt, Mode::Llm42, &patterns[1], FaultPlan::None);
    assert_eq!(streams[0], again);
}

#[test]
fn fast_path_logits_diverge_across_bucket_trajectories() {
    // The mechanism (paper Fig. 3 / O1): the same token through different
    // batch buckets takes a different split-K reduction tree, so its
    // logits are bitwise different. Token-level flips are then a
    // *statistical* consequence measured by the Fig. 6 harness; here we
    // assert the deterministic part bitwise.
    let mut rt = Runtime::load(artifacts_dir()).unwrap();
    let vocab = rt.dims().vocab;
    let trash = (rt.dims().slots - 1) as i32;

    // same token, same slot 0, same position, as lane 0 of bucket 1 vs 4
    rt.reset_state().unwrap();
    rt.forward("decode_fast_b1", &[42], &[0], &[0]).unwrap();
    let l1 = rt.extract_logits(1).unwrap().to_vec();

    rt.reset_state().unwrap();
    rt.forward(
        "decode_fast_b4",
        &[42, 43, 44, 45],
        &[0, 1, 2, trash],
        &[0, 0, 0, 0],
    )
    .unwrap();
    let l4 = rt.extract_logits(4).unwrap().to_vec();

    let same_bits = l1[..vocab]
        .iter()
        .zip(&l4[..vocab])
        .all(|(a, b)| a.to_bits() == b.to_bits());
    assert!(
        !same_bits,
        "bucket-1 and bucket-4 schedules must produce different logits"
    );
    // ...but the drift is small: same argmax ordering magnitude-wise
    let max_diff = l1[..vocab]
        .iter()
        .zip(&l4[..vocab])
        .map(|(a, b)| (a - b).abs())
        .fold(0f32, f32::max);
    assert!(max_diff < 1.0, "drift should be perturbative, got {max_diff}");

    // per-schedule determinism (O2): re-running bucket 4 is bitwise equal
    rt.reset_state().unwrap();
    rt.forward(
        "decode_fast_b4",
        &[42, 43, 44, 45],
        &[0, 1, 2, trash],
        &[0, 0, 0, 0],
    )
    .unwrap();
    let l4b = rt.extract_logits(4).unwrap().to_vec();
    assert!(l4
        .iter()
        .zip(&l4b)
        .all(|(a, b)| a.to_bits() == b.to_bits()));

    // control at stream level: identical co-traffic -> identical stream
    let co = vec![co_request(300, 48)];
    let (a, _, _, _) =
        run_with_cotraffic(&mut rt, Mode::NonDeterministic, &co, FaultPlan::None);
    let (b, _, _, _) =
        run_with_cotraffic(&mut rt, Mode::NonDeterministic, &co, FaultPlan::None);
    assert_eq!(a, b);
}

#[test]
fn llm42_output_matches_batch_invariant_reference() {
    // Both enforce determinism; they must agree with THEMSELVES across
    // runs. (They need not agree with each other: the verifier's fixed
    // schedule and the batch-invariant schedule are different fixed
    // schedules — determinism is per-system, as in the paper.)
    let mut rt = Runtime::load(artifacts_dir()).unwrap();
    let (inv_a, _, _, _) =
        run_with_cotraffic(&mut rt, Mode::BatchInvariant, &[], FaultPlan::None);
    let co = vec![co_request(400, 32)];
    let (inv_b, _, _, _) =
        run_with_cotraffic(&mut rt, Mode::BatchInvariant, &co, FaultPlan::None);
    assert_eq!(inv_a, inv_b, "batch-invariant mode must be batch-insensitive");
}

#[test]
fn forced_rollbacks_preserve_output_and_forward_progress() {
    let mut rt = Runtime::load(artifacts_dir()).unwrap();

    let (clean, _, rb_clean, _) =
        run_with_cotraffic(&mut rt, Mode::Llm42, &[], FaultPlan::None);

    // fault injection: every verification lane reports a mismatch at the
    // first window position -> maximum rollback pressure
    let (faulted, _, rb_fault, recomputed) = run_with_cotraffic(
        &mut rt,
        Mode::Llm42,
        &[],
        FaultPlan::EveryNthLane { every: 1, at_index: 0 },
    );
    assert!(rb_fault > rb_clean, "fault injection must trigger rollbacks");
    assert!(recomputed > 0);
    // the committed stream still comes from the verifier's deterministic
    // replay, so the output is unchanged — rollbacks cost work, not truth
    assert_eq!(clean, faulted);
}

#[test]
fn eos_and_length_edges_respect_limits() {
    let mut rt = Runtime::load(artifacts_dir()).unwrap();
    let mut eng = Engine::new(&mut rt, cfg(Mode::Llm42)).unwrap();

    // max_new_tokens = 1: prefill commits the only token
    let id1 = eng
        .submit(Request {
            prompt: (10..20).collect(),
            max_new_tokens: 1,
            deterministic: true,
            temperature: 0.0,
            seed: 0,
            ..Default::default()
        })
        .unwrap();
    // a deterministic request that stops mid-window
    let id2 = eng
        .submit(Request {
            prompt: (40..56).collect(),
            max_new_tokens: 5,
            deterministic: true,
            temperature: 1.0,
            seed: 9,
            ..Default::default()
        })
        .unwrap();
    eng.run_to_completion().unwrap();
    let outs = eng.take_finished();
    let o1 = outs.iter().find(|o| o.id == id1).unwrap();
    let o2 = outs.iter().find(|o| o.id == id2).unwrap();
    assert_eq!(o1.tokens.len(), 1);
    assert!(o2.tokens.len() <= 5);
    assert!(!o2.tokens.is_empty());

    // oversized requests are rejected up front
    let too_big = Request {
        prompt: vec![5; 600],
        max_new_tokens: 100,
        deterministic: true,
        temperature: 0.0,
        seed: 0,
        ..Default::default()
    };
    assert!(eng.submit(too_big).is_err());
    // out-of-vocab prompt rejected
    let bad = Request {
        prompt: vec![1_000_000],
        max_new_tokens: 4,
        deterministic: false,
        temperature: 0.0,
        seed: 0,
        ..Default::default()
    };
    assert!(eng.submit(bad).is_err());
}

#[test]
fn every_policy_preserves_deterministic_streams_across_cotraffic() {
    // Acceptance criterion for the scheduler/executor split: under every
    // scheduling policy, Mode::Llm42 yields identical committed tokens for
    // deterministic requests across runs with *different* background
    // traffic — scheduling reorders work, never results. The backgrounds
    // differ in count, length, priority, and deadlines, so the deadline /
    // fair-share runs take genuinely different admission, verification,
    // and preemption paths.
    let mut rt = Runtime::load(artifacts_dir()).unwrap();

    let bg = |seed: u64, len: usize, priority: u8, deadline: Option<f64>| Request {
        prompt: (30..30 + 12).collect(),
        max_new_tokens: len,
        deterministic: false,
        temperature: 1.0,
        seed,
        priority,
        deadline_ms: deadline,
        ..Default::default()
    };
    let backgrounds: Vec<Vec<Request>> = vec![
        vec![],
        vec![bg(500, 40, 0, None), bg(501, 24, 3, Some(400.0))],
        vec![
            bg(600, 16, 0, None),
            bg(601, 48, 2, Some(150.0)),
            bg(602, 32, 1, None),
            bg(603, 20, 3, Some(50.0)),
        ],
    ];

    for policy in [
        PolicyKind::PrefillFirst,
        PolicyKind::DeadlineAware,
        PolicyKind::FairShare,
    ] {
        let mut streams: Vec<Vec<u32>> = Vec::new();
        for pat in &backgrounds {
            let mut c = cfg(Mode::Llm42);
            c.policy = policy;
            let mut eng = Engine::new(&mut rt, c).unwrap();
            let mut det = det_request(7);
            det.priority = 2;
            det.deadline_ms = Some(800.0);
            let det_id = eng.submit(det).unwrap();
            for r in pat {
                eng.submit(r.clone()).unwrap();
            }
            eng.run_to_completion().unwrap();
            let outs = eng.take_finished();
            assert_eq!(outs.len(), pat.len() + 1, "{policy:?}: all requests finish");
            let out = outs.iter().find(|o| o.id == det_id).unwrap();
            assert!(!out.tokens.is_empty());
            streams.push(out.tokens.clone());
        }
        assert_eq!(streams[0], streams[1], "{policy:?}: bg pattern 1");
        assert_eq!(streams[0], streams[2], "{policy:?}: bg pattern 2");
    }
}

#[test]
fn prefix_cache_is_bitwise_invisible_across_all_policies() {
    // Acceptance criterion for the paged-KV subsystem: with the prefix
    // cache enabled, deterministic requests' committed tokens are bitwise
    // identical to cache-off runs under every scheduling policy — cache
    // hits skip prefill *compute*, never verification, and adopted pages
    // hold invariant-schedule KV that is a pure function of the tokens.
    let mut rt = Runtime::load(artifacts_dir()).unwrap();

    // prefix-heavy workload: three deterministic requests sharing a long
    // common prompt prefix (plus nondet co-traffic on the same prefix)
    let shared: Vec<u32> = (100..148).collect(); // 48 tokens = 3 blocks
    let reqs = |base_seed: u64| -> Vec<Request> {
        (0..5u64)
            .map(|i| {
                let mut prompt = shared.clone();
                prompt.extend((200 + 3 * i as u32)..(200 + 3 * i as u32 + 4));
                Request {
                    prompt,
                    max_new_tokens: 12 + i as usize,
                    deterministic: i < 3,
                    temperature: 1.0,
                    seed: base_seed + i,
                    priority: (i % 3) as u8,
                    deadline_ms: if i == 1 { Some(400.0) } else { None },
                    ..Default::default()
                }
            })
            .collect()
    };

    for policy in [
        PolicyKind::PrefillFirst,
        PolicyKind::DeadlineAware,
        PolicyKind::FairShare,
    ] {
        let mut run = |rt: &mut Runtime, cache: bool| -> (Vec<(u64, Vec<u32>)>, u64) {
            let mut c = cfg(Mode::Llm42);
            c.policy = policy;
            c.prefix_cache = cache;
            let mut eng = Engine::new(rt, c).unwrap();
            let all = reqs(7);
            // the first request lands alone and prefills the shared prefix
            // (publishing its blocks when the cache is on); the rest arrive
            // a fixed three steps later — same schedule in both runs
            eng.submit(all[0].clone()).unwrap();
            for _ in 0..3 {
                eng.step().unwrap();
            }
            for r in &all[1..] {
                eng.submit(r.clone()).unwrap();
            }
            eng.run_to_completion().unwrap();
            let outs = eng.take_finished();
            let mut det: Vec<(u64, Vec<u32>)> = outs
                .iter()
                .filter(|o| o.deterministic)
                .map(|o| (o.id, o.tokens.clone()))
                .collect();
            det.sort();
            (det, eng.metrics.cache_hit_tokens)
        };
        let (off, hits_off) = run(&mut rt, false);
        let (on, hits_on) = run(&mut rt, true);
        assert_eq!(hits_off, 0, "{policy:?}: cache off must not hit");
        assert!(
            hits_on > 0,
            "{policy:?}: the shared 48-token prefix must produce cache hits"
        );
        assert_eq!(off, on, "{policy:?}: committed streams must match bitwise");
    }
}

#[test]
fn rollback_under_sharing_keeps_shared_pages_pristine() {
    // The COW satellite: a verifier mismatch rolls back a sequence whose
    // prefix blocks are referenced by another live sequence. The rewrite
    // must not corrupt the shared pages — the hitter's stream and future
    // hits of the same prefix stay bitwise identical to cache-off runs.
    let mut rt = Runtime::load(artifacts_dir()).unwrap();
    let prompt_a: Vec<u32> = (60..92).collect(); // 32 tokens = 2 full blocks

    let run = |rt: &mut Runtime, cache: bool, tokens_a_hint: &[u32]| {
        let mut c = cfg(Mode::Llm42);
        c.verify_window = 8;
        c.prefix_cache = cache;
        c.eos_token = 9999; // out of vocab: both sequences run full budgets
        // every verify pass reports a mismatch at window position 0:
        // maximum rollback pressure while prefix blocks are shared
        c.fault = FaultPlan::EveryNthLane { every: 1, at_index: 0 };
        let mut eng = Engine::new(rt, c).unwrap();
        let id_a = eng
            .submit(Request {
                prompt: prompt_a.clone(),
                max_new_tokens: 24,
                deterministic: true,
                temperature: 1.0,
                seed: 11,
                ..Default::default()
            })
            .unwrap();
        // B arrives once A has committed enough for its blocks to be
        // published, with a prompt that extends A's committed history
        // (the multi-turn follow-up shape)
        let mut id_b = None;
        for _ in 0..10_000 {
            if eng.idle() {
                break;
            }
            eng.step().unwrap();
            if id_b.is_none() && !tokens_a_hint.is_empty() {
                let committed_a = eng
                    .view()
                    .lanes
                    .iter()
                    .find(|l| l.id == id_a)
                    .map(|l| l.committed)
                    .unwrap_or(usize::MAX);
                if committed_a >= 18 && committed_a != usize::MAX {
                    let mut p = prompt_a.clone();
                    p.extend(tokens_a_hint[..16].iter().copied());
                    p.push(300);
                    id_b = Some(
                        eng.submit(Request {
                            prompt: p,
                            max_new_tokens: 10,
                            deterministic: true,
                            temperature: 1.0,
                            seed: 12,
                            ..Default::default()
                        })
                        .unwrap(),
                    );
                }
            }
        }
        eng.run_to_completion().unwrap();
        let outs = eng.take_finished();
        let toks = |id: u64| outs.iter().find(|o| o.id == id).unwrap().tokens.clone();
        (
            toks(id_a),
            id_b.map(toks),
            eng.metrics.rollbacks,
            eng.metrics.cache_hit_tokens,
            eng.metrics.cow_copies,
        )
    };

    // learn A's deterministic stream (cache off, solo)
    let (tokens_a, _, rb, _, _) = run(&mut rt, false, &[]);
    assert!(rb > 0, "fault injection must force rollbacks");
    assert!(tokens_a.len() >= 18);

    // cache-off reference for the shared scenario
    let (ref_a, ref_b, _, hits_off, _) = run(&mut rt, false, &tokens_a);
    assert_eq!(ref_a, tokens_a);
    assert_eq!(hits_off, 0);

    // cache on: B adopts A's published blocks while A keeps rolling back
    let (on_a, on_b, rb_on, hits_on, cow) = run(&mut rt, true, &tokens_a);
    assert!(rb_on > 0);
    assert!(hits_on > 0, "B must hit A's published prefix blocks");
    assert_eq!(on_a, tokens_a, "the rolled-back sharer stays bitwise identical");
    assert_eq!(on_b, ref_b, "the hitter stays bitwise identical");
    // The publish limit ends strictly below every write frontier, so the
    // window rewrite never overlaps a published/shared page and COW — the
    // enforcement mechanism guarding exactly this scenario — stays idle.
    // If a future publisher widens the limit, this flips and the rewrite
    // must copy first (prepare_write already does; see engine/kv tests).
    assert_eq!(cow, 0, "no live write path may touch a shared page");
}

#[test]
fn greedy_zero_temperature_is_deterministic_even_without_dvr() {
    // a sanity baseline: greedy + identical batching reproduces exactly
    let mut rt = Runtime::load(artifacts_dir()).unwrap();
    let req = Request {
        prompt: (10..26).collect(),
        max_new_tokens: 24,
        deterministic: false,
        temperature: 0.0,
        seed: 0,
        ..Default::default()
    };
    let mut run = |rt: &mut Runtime| {
        let mut eng = Engine::new(rt, cfg(Mode::NonDeterministic)).unwrap();
        eng.submit(req.clone()).unwrap();
        eng.run_to_completion().unwrap();
        eng.take_finished().pop().unwrap().tokens
    };
    let a = run(&mut rt);
    let b = run(&mut rt);
    assert_eq!(a, b);
}
