# Build entry points. The real AOT path (python/compile, JAX + PJRT) is
# unavailable in the offline image; `artifacts` uses the rust generator,
# which emits the simulator descriptor format (see rust/src/aot.rs).

CARGO ?= cargo

.PHONY: artifacts artifacts-test build test test-threads test-server test-gate test-tp test-router fmt-check lint doc bench-check bench-json

artifacts:
	cd rust && $(CARGO) run --release -- gen-artifacts --out artifacts --preset tiny

artifacts-test:
	cd rust && $(CARGO) run --release -- gen-artifacts --out artifacts --preset test

build:
	cd rust && $(CARGO) build --release

test:
	cd rust && $(CARGO) test -q

# The CI matrix locally: the whole suite under the sequential backend and
# again at 4 simulator worker threads — results must be identical.
test-threads:
	cd rust && LLM42_THREADS=1 $(CARGO) test -q
	cd rust && LLM42_THREADS=4 $(CARGO) test -q

# The margin-gate matrix locally (mirrors the CI determinism-audit job):
# the verify-policy suite at 1 and 4 simulator threads, then the audit
# example gate off vs on — the deterministic digest lines (audit_digest=,
# det_engine_digest=) must be bit-identical across triggers.
test-gate:
	cd rust && LLM42_THREADS=1 $(CARGO) test -q --test verify_policy
	cd rust && LLM42_THREADS=4 $(CARGO) test -q --test verify_policy
	cd rust && $(CARGO) run --release --example determinism_audit \
		| grep -E '^(audit_digest|det_engine_digest)=' > /tmp/llm42_gate_off
	cd rust && $(CARGO) run --release --example determinism_audit -- \
		--verify-policy margin-gate \
		| grep -E '^(audit_digest|det_engine_digest)=' > /tmp/llm42_gate_on
	diff -u /tmp/llm42_gate_off /tmp/llm42_gate_on
	@echo "gate on/off deterministic digests identical"

# The tensor-parallel matrix locally (mirrors the CI cross-R audit): the
# tp suite pins bitwise-identical streams/digests at R=1,2,4 under the
# tree and multimem collectives (and ring's divergence), then the audit
# example runs at each R with the tree collective — the engine_digest=
# lines must be bit-identical across rank counts.
test-tp:
	cd rust && $(CARGO) test -q --test tp
	cd rust && $(CARGO) run --release --example determinism_audit -- \
		--tp 1 --collective tree \
		| grep -E '^engine_digest=' > /tmp/llm42_tp_r1
	cd rust && $(CARGO) run --release --example determinism_audit -- \
		--tp 2 --collective tree \
		| grep -E '^engine_digest=' > /tmp/llm42_tp_r2
	cd rust && $(CARGO) run --release --example determinism_audit -- \
		--tp 4 --collective tree \
		| grep -E '^engine_digest=' > /tmp/llm42_tp_r4
	diff -u /tmp/llm42_tp_r1 /tmp/llm42_tp_r2
	diff -u /tmp/llm42_tp_r1 /tmp/llm42_tp_r4
	@echo "cross-R engine digests identical (tree collective)"

# The multi-replica matrix locally (mirrors the CI router job): the
# router suite (cross-replica determinism, failover/poisoning, the
# affinity soak, backpressure shedding) at 1 and 4 simulator threads,
# then the audit example at 1, 2, 4 replicas — the fleet_digest= lines
# (the router's fold over global ids) must be bit-identical across
# replica counts.
test-router:
	cd rust && LLM42_THREADS=1 $(CARGO) test -q --test router
	cd rust && LLM42_THREADS=4 $(CARGO) test -q --test router
	cd rust && $(CARGO) run --release --example determinism_audit -- \
		--replicas 1 | grep -E '^fleet_' > /tmp/llm42_router_n1
	cd rust && $(CARGO) run --release --example determinism_audit -- \
		--replicas 2 | grep -E '^fleet_' > /tmp/llm42_router_n2
	cd rust && $(CARGO) run --release --example determinism_audit -- \
		--replicas 4 | grep -E '^fleet_' > /tmp/llm42_router_n4
	diff -u /tmp/llm42_router_n1 /tmp/llm42_router_n2
	diff -u /tmp/llm42_router_n1 /tmp/llm42_router_n4
	@echo "cross-replica fleet digests identical"

# Serving-surface integration: stream + cancel + timeout over a real
# socket, disconnect detection, poisoned-engine lifecycle, abort matrix.
test-server:
	cd rust && $(CARGO) test --test server --test abort --test streaming

fmt-check:
	cd rust && $(CARGO) fmt --check

lint:
	cd rust && $(CARGO) clippy --all-targets -- -D warnings

# API docs; broken intra-doc links are errors (mirrors the CI docs job)
doc:
	cd rust && RUSTDOCFLAGS="-D warnings" $(CARGO) doc --no-deps

bench-check:
	cd rust && $(CARGO) bench --no-run

# Run the engine bench suite; writes the machine-readable perf trajectory
# to BENCH_engine.json at the repo root (see benches/engine.rs).
bench-json:
	cd rust && $(CARGO) bench --bench engine
