//! Kernel micro-benchmarks (Fig. 4 material): fast split-K vs the
//! batch-invariant universal schedule, through the rust/PJRT path.
//!
//! The offline vendor set has no criterion; this is a plain
//! `harness = false` bench binary that prints min/avg tables.
//!
//!     cargo bench --bench kernels

use llm42::runtime::Runtime;
use llm42::util::rng::SplitMix64;
use llm42::util::stats::Table;

fn main() {
    let artifacts =
        std::env::var("LLM42_ARTIFACTS").unwrap_or_else(|_| "artifacts".into());
    let _ = llm42::aot::ensure(&artifacts);
    let rt = match Runtime::load(&artifacts) {
        Ok(rt) => rt,
        Err(e) => {
            eprintln!("bench skipped: {e}");
            return;
        }
    };
    if rt.manifest.artifact("gemm_fast_m1").is_none() {
        eprintln!("bench skipped: micro artifacts missing (make artifacts-micro)");
        return;
    }
    let dims = rt.dims().clone();
    let (k, n) = (dims.ffn_hidden, dims.d_model);
    let mut rng = SplitMix64::new(3);
    let reps = 30;

    let mut tab = Table::new(&[
        "kernel", "m", "min_us", "avg_us", "gflops(avg)",
    ]);
    for &m in &[1usize, 8, 64, 512] {
        let x: Vec<f32> = (0..m * k).map(|_| rng.normal() as f32).collect();
        let w: Vec<f32> = (0..k * n).map(|_| rng.normal() as f32).collect();
        for variant in ["fast", "inv"] {
            let name = format!("gemm_{variant}_m{m}");
            let _ = rt.run_micro(&name, (&x, &[m, k]), (&w, &[k, n]));
            let mut min = f64::MAX;
            let mut sum = 0.0;
            for _ in 0..reps {
                let t = rt.run_micro(&name, (&x, &[m, k]), (&w, &[k, n])).unwrap();
                min = min.min(t);
                sum += t;
            }
            let avg = sum / reps as f64;
            tab.row(vec![
                format!("gemm_{variant}"),
                m.to_string(),
                format!("{:.1}", min * 1e6),
                format!("{:.1}", avg * 1e6),
                format!("{:.2}", 2.0 * (m * k * n) as f64 / avg / 1e9),
            ]);
        }
        let xn: Vec<f32> = (0..m * dims.d_model).map(|_| rng.normal() as f32).collect();
        let wn = vec![1.0f32; dims.d_model];
        for variant in ["fast", "inv"] {
            let name = format!("rmsnorm_{variant}_m{m}");
            let _ = rt.run_micro(&name, (&xn, &[m, dims.d_model]), (&wn, &[dims.d_model]));
            let mut min = f64::MAX;
            let mut sum = 0.0;
            for _ in 0..reps {
                let t = rt
                    .run_micro(&name, (&xn, &[m, dims.d_model]), (&wn, &[dims.d_model]))
                    .unwrap();
                min = min.min(t);
                sum += t;
            }
            tab.row(vec![
                format!("rmsnorm_{variant}"),
                m.to_string(),
                format!("{:.1}", min * 1e6),
                format!("{:.1}", sum / reps as f64 * 1e6),
                "-".into(),
            ]);
        }
    }
    println!("{}", tab.render());
}
