//! Engine-path benchmarks: decode step per bucket (fast vs invariant),
//! verify pass, prefill chunk, logits extraction, and the pure-rust hot
//! pieces (sampler, batch bookkeeping) that must never dominate L3.
//!
//!     cargo bench --bench engine

use llm42::engine::sampler::sample;
use llm42::runtime::Runtime;
use llm42::util::rng::SplitMix64;
use llm42::util::stats::Table;

fn main() {
    let artifacts =
        std::env::var("LLM42_ARTIFACTS").unwrap_or_else(|_| "artifacts".into());
    let mut rt = match Runtime::load(&artifacts) {
        Ok(rt) => rt,
        Err(e) => {
            eprintln!("bench skipped: {e}");
            return;
        }
    };
    let dims = rt.dims().clone();
    let trash = (dims.slots - 1) as i32;
    let reps = 20;

    // ---- forward passes ---------------------------------------------------
    let mut tab = Table::new(&["pass", "avg_ms", "per_token_us"]);
    let mut fwd = |rt: &mut Runtime, name: &str, g: usize, t: usize, tab: &mut Table| {
        let tokens = vec![3i32; g * t];
        let slots = vec![trash; g];
        let pos = vec![0i32; g];
        if rt.manifest.artifact(name).is_none() {
            return;
        }
        rt.forward(name, &tokens, &slots, &pos).unwrap();
        let t0 = std::time::Instant::now();
        for _ in 0..reps {
            rt.forward(name, &tokens, &slots, &pos).unwrap();
            rt.extract_logits(g * t).unwrap();
        }
        let avg = t0.elapsed().as_secs_f64() / reps as f64;
        tab.row(vec![
            name.to_string(),
            format!("{:.2}", avg * 1e3),
            format!("{:.1}", avg / (g * t) as f64 * 1e6),
        ]);
    };
    for b in [1usize, 4, 16] {
        fwd(&mut rt, &format!("decode_fast_b{b}"), b, 1, &mut tab);
        fwd(&mut rt, &format!("decode_inv_b{b}"), b, 1, &mut tab);
    }
    fwd(&mut rt, "window_inv_g1_t64", 1, 64, &mut tab); // prefill chunk
    fwd(&mut rt, "window_inv_g8_t32", 8, 32, &mut tab); // grouped verify
    println!("{}", tab.render());

    // ---- pure-rust hot pieces ----------------------------------------------
    let mut rng = SplitMix64::new(1);
    let vocab = dims.vocab;
    let logits: Vec<f32> = (0..vocab).map(|_| rng.normal() as f32).collect();
    let mut tab = Table::new(&["component", "ns_per_call", "calls_per_decode_step"]);

    let t0 = std::time::Instant::now();
    let iters = 2000u64;
    let mut sink = 0u32;
    for i in 0..iters {
        sink ^= sample(&logits, 1.0, 42, i);
    }
    let per = t0.elapsed().as_nanos() as f64 / iters as f64;
    tab.row(vec![
        "sampler (gumbel, V=2048)".into(),
        format!("{per:.0}"),
        "1 per lane".into(),
    ]);

    let t0 = std::time::Instant::now();
    for i in 0..iters {
        sink ^= sample(&logits, 0.0, 42, i);
    }
    let per = t0.elapsed().as_nanos() as f64 / iters as f64;
    tab.row(vec![
        "sampler (greedy, V=2048)".into(),
        format!("{per:.0}"),
        "1 per lane".into(),
    ]);
    std::hint::black_box(sink);
    println!("{}", tab.render());
    println!(
        "note: sampler cost per 16-lane decode step ≈ {:.2} ms vs ~25 ms forward — \
         L3 is not the bottleneck (DESIGN.md §9 target)",
        16.0 * per / 1e6
    );
}
