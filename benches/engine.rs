//! Engine-path benchmarks: decode step per bucket (fast vs invariant),
//! verify pass, prefill chunk, logits extraction, the pure-rust hot
//! pieces (sampler, batch bookkeeping) that must never dominate L3, and a
//! mixed-traffic scheduling-policy comparison (p99 deterministic e2e under
//! a saturating low-priority background load).
//!
//!     cargo bench --bench engine

use llm42::engine::{
    Engine, EngineConfig, Mode, PolicyKind, Request, StepKind,
};
use llm42::runtime::Runtime;
use llm42::engine::sampler::sample;
use llm42::util::rng::SplitMix64;
use llm42::util::stats::{Recorder, Table};

fn main() {
    let artifacts =
        std::env::var("LLM42_ARTIFACTS").unwrap_or_else(|_| "artifacts".into());
    let _ = llm42::aot::ensure(&artifacts);
    let mut rt = match Runtime::load(&artifacts) {
        Ok(rt) => rt,
        Err(e) => {
            eprintln!("bench skipped: {e}");
            return;
        }
    };
    let dims = rt.dims().clone();
    let trash = (dims.slots - 1) as i32;
    let reps = 20;

    // ---- forward passes ---------------------------------------------------
    let mut tab = Table::new(&["pass", "avg_ms", "per_token_us"]);
    let mut fwd = |rt: &mut Runtime, name: &str, g: usize, t: usize, tab: &mut Table| {
        let tokens = vec![3i32; g * t];
        let slots = vec![trash; g];
        let pos = vec![0i32; g];
        if rt.manifest.artifact(name).is_none() {
            return;
        }
        rt.forward(name, &tokens, &slots, &pos).unwrap();
        let t0 = std::time::Instant::now();
        for _ in 0..reps {
            rt.forward(name, &tokens, &slots, &pos).unwrap();
            rt.extract_logits(g * t).unwrap();
        }
        let avg = t0.elapsed().as_secs_f64() / reps as f64;
        tab.row(vec![
            name.to_string(),
            format!("{:.2}", avg * 1e3),
            format!("{:.1}", avg / (g * t) as f64 * 1e6),
        ]);
    };
    for b in [1usize, 4, 16] {
        fwd(&mut rt, &format!("decode_fast_b{b}"), b, 1, &mut tab);
        fwd(&mut rt, &format!("decode_inv_b{b}"), b, 1, &mut tab);
    }
    fwd(&mut rt, "window_inv_g1_t64", 1, 64, &mut tab); // prefill chunk
    fwd(&mut rt, "window_inv_g8_t32", 8, 32, &mut tab); // grouped verify
    println!("{}", tab.render());

    // ---- pure-rust hot pieces ----------------------------------------------
    let mut rng = SplitMix64::new(1);
    let vocab = dims.vocab;
    let logits: Vec<f32> = (0..vocab).map(|_| rng.normal() as f32).collect();
    let mut tab = Table::new(&["component", "ns_per_call", "calls_per_decode_step"]);

    let t0 = std::time::Instant::now();
    let iters = 2000u64;
    let mut sink = 0u32;
    for i in 0..iters {
        sink ^= sample(&logits, 1.0, 42, i);
    }
    let per = t0.elapsed().as_nanos() as f64 / iters as f64;
    tab.row(vec![
        "sampler (gumbel, V=2048)".into(),
        format!("{per:.0}"),
        "1 per lane".into(),
    ]);

    let t0 = std::time::Instant::now();
    for i in 0..iters {
        sink ^= sample(&logits, 0.0, 42, i);
    }
    let per = t0.elapsed().as_nanos() as f64 / iters as f64;
    tab.row(vec![
        "sampler (greedy, V=2048)".into(),
        format!("{per:.0}"),
        "1 per lane".into(),
    ]);
    std::hint::black_box(sink);
    println!("{}", tab.render());
    println!(
        "note: sampler cost per 16-lane decode step ≈ {:.2} ms vs ~25 ms forward — \
         L3 is not the bottleneck (DESIGN.md §9 target)",
        16.0 * per / 1e6
    );

    policy_comparison(&mut rt);
    multiturn_cache_comparison(&mut rt);
}

/// Multi-turn chat, closed loop: every follow-up turn resubmits the
/// committed history (shared system prompt + prior turns), the
/// prefix-cache-heavy workload class. Reports prefill tokens computed vs
/// served from cache and deterministic TTFT with the cache off vs on —
/// the paged-KV acceptance measurement (>= 30% prefill-token reduction
/// from cache hits on this shape).
fn multiturn_cache_comparison(rt: &mut Runtime) {
    let mut tab = Table::new(&[
        "prefix_cache",
        "prefill_tok",
        "cache_hit_tok",
        "prefill_saved_%",
        "ttft_p50_ms",
        "ttft_p99_ms",
    ]);
    let n_convs = 4usize;
    let turns = 5usize;
    let mut baseline_prefill = 0u64;
    for cache in [false, true] {
        let cfg = EngineConfig {
            mode: Mode::Llm42,
            verify_group: 4,
            verify_window: 16,
            max_stall_steps: 4,
            eos_token: u32::MAX, // full budgets: identical turn shapes
            prefix_cache: cache,
            ..Default::default()
        };
        let mut eng = match Engine::new(rt, cfg) {
            Ok(e) => e,
            Err(e) => {
                eprintln!("multiturn bench skipped: {e}");
                return;
            }
        };
        let _ = eng.warmup();

        // identical shared system prompt across every conversation
        let system: Vec<u32> = (40..64).collect();
        let mut histories: Vec<Vec<u32>> = vec![system.clone(); n_convs];
        let mut ttft = Recorder::new();
        for turn in 0..turns {
            let mut wave: Vec<(u64, usize)> = Vec::new();
            for c in 0..n_convs {
                let mut prompt = histories[c].clone();
                for k in 0..6usize {
                    prompt.push(70 + ((turn * 13 + c * 7 + k) as u32 % 300));
                }
                histories[c] = prompt.clone();
                let id = eng
                    .submit(Request {
                        prompt,
                        max_new_tokens: 8,
                        deterministic: true,
                        temperature: 1.0,
                        seed: (turn * n_convs + c) as u64,
                        priority: 0,
                        deadline_ms: None,
                    })
                    .unwrap();
                wave.push((id, c));
            }
            if let Err(e) = eng.run_to_completion() {
                eprintln!("multiturn bench aborted: {e}");
                return;
            }
            // closed loop: append each reply's committed tokens to its
            // conversation before the next turn resubmits the history
            let outs = eng.take_finished();
            for (id, c) in wave {
                let o = outs.iter().find(|o| o.id == id).expect("turn finished");
                histories[c].extend(o.tokens.iter().copied());
                ttft.record(o.metrics.ttft() * 1e3);
            }
        }
        let prefill = eng.metrics.prefill_tokens;
        let hits = eng.metrics.cache_hit_tokens;
        if !cache {
            baseline_prefill = prefill;
        }
        let saved = if cache && baseline_prefill > 0 {
            100.0 * (baseline_prefill.saturating_sub(prefill)) as f64
                / baseline_prefill as f64
        } else {
            0.0
        };
        tab.row(vec![
            format!("{cache}"),
            format!("{prefill}"),
            format!("{hits}"),
            format!("{saved:.0}"),
            format!("{:.0}", ttft.percentile(50.0)),
            format!("{:.0}", ttft.percentile(99.0)),
        ]);
    }
    println!("== multiturn chat: prefix cache off vs on ==");
    println!("{}", tab.render());
}

/// Mixed-traffic policy benchmark: a handful of high-priority deterministic
/// requests arrive while a saturating low-priority non-deterministic
/// background occupies every KV slot. Reports per-policy p50/p99
/// deterministic e2e plus preemption/re-prefill cost — the scheduler split's
/// acceptance measurement (DeadlineAware/FairShare should cut the
/// deterministic tail vs the seed PrefillFirst policy).
fn policy_comparison(rt: &mut Runtime) {
    let user_slots = rt.dims().slots - 1;
    let mut tab = Table::new(&[
        "policy",
        "det_p50_ms",
        "det_p99_ms",
        "bg_p99_ms",
        "preemptions",
        "reprefilled",
        "wall_s",
    ]);
    for policy in [
        PolicyKind::PrefillFirst,
        PolicyKind::DeadlineAware,
        PolicyKind::FairShare,
    ] {
        let cfg = EngineConfig {
            mode: Mode::Llm42,
            verify_group: 2,
            verify_window: 16,
            max_stall_steps: 4,
            eos_token: u32::MAX, // run full length budgets: stable load
            policy,
            ..Default::default()
        };
        let mut eng = match Engine::new(rt, cfg) {
            Ok(e) => e,
            Err(e) => {
                eprintln!("policy bench skipped: {e}");
                return;
            }
        };
        let _ = eng.warmup();

        // saturating background: 4x as many low-priority requests as
        // slots, long budgets — keeps every slot contended for the whole
        // deterministic arrival window
        let n_bg = user_slots * 4;
        for i in 0..n_bg {
            eng.submit(Request {
                prompt: (10..26).map(|t| t + (i as u32 % 7)).collect(),
                max_new_tokens: 96,
                deterministic: false,
                temperature: 1.0,
                seed: 40_000 + i as u64,
                priority: 0,
                deadline_ms: None,
            })
            .unwrap();
        }
        // high-priority deterministic requests arrive once the background
        // is decoding (trickled in as the run progresses); enough samples
        // that the p99 column is a tail estimate, not a single max
        let det_every = 15usize; // steps between deterministic arrivals
        let n_det = 24usize;
        let mut det_submitted = 0usize;
        let mut steps = 0usize;
        let t0 = llm42::util::now_secs();
        loop {
            if det_submitted < n_det && steps == det_every * (det_submitted + 1) {
                eng.submit(Request {
                    prompt: (30..42).collect(),
                    max_new_tokens: 16,
                    deterministic: true,
                    temperature: 1.0,
                    seed: 7 + det_submitted as u64,
                    priority: 4,
                    deadline_ms: Some(250.0),
                })
                .unwrap();
                det_submitted += 1;
            }
            if det_submitted >= n_det && eng.idle() {
                break;
            }
            match eng.step() {
                Ok(StepKind::Idle) => {
                    if det_submitted >= n_det {
                        break;
                    }
                    // waiting for the next scripted arrival
                }
                Ok(_) => {}
                Err(e) => {
                    eprintln!("policy bench aborted: {e}");
                    return;
                }
            }
            steps += 1;
        }
        let wall = llm42::util::now_secs() - t0;

        let outs = eng.take_finished();
        let mut det_e2e = Recorder::new();
        let mut bg_e2e = Recorder::new();
        for o in &outs {
            if o.deterministic {
                det_e2e.record(o.metrics.e2e() * 1e3);
            } else {
                bg_e2e.record(o.metrics.e2e() * 1e3);
            }
        }
        tab.row(vec![
            eng.policy_name().to_string(),
            format!("{:.0}", det_e2e.percentile(50.0)),
            format!("{:.0}", det_e2e.percentile(99.0)),
            format!("{:.0}", bg_e2e.percentile(99.0)),
            format!("{}", eng.metrics.preemptions),
            format!("{}", eng.metrics.reprefilled_tokens),
            format!("{wall:.1}"),
        ]);
    }
    println!("== mixed traffic: policy comparison ==");
    println!("{}", tab.render());
}
