//! Engine-path benchmarks: decode step per bucket (fast vs invariant),
//! verify pass, prefill chunk, logits extraction, the pure-rust hot
//! pieces (sampler, batch bookkeeping) that must never dominate L3, a
//! mixed-traffic scheduling-policy comparison (p99 deterministic e2e under
//! a saturating low-priority background load), a step-composer comparison
//! (fusion off vs on at equal max_batch), and a churn soak (steady-state
//! tok/s early vs late in a 10k-request closed loop — flat numbers prove
//! per-step cost is O(live), not O(requests served)).
//!
//!     cargo bench --bench engine
//!
//! Besides the human-readable tables, the closed-loop benches write a
//! machine-readable perf trajectory to `BENCH_engine.json` at the repo
//! root (tok/s, TTFT p50/p99, det-traffic e2e p99, forwards per committed
//! token) so future PRs can diff perf. Env knobs:
//!   * `LLM42_BENCH_JSON=path` — override the output path
//!   * `LLM42_BENCH_REDUCED=1` — shrink reps/workloads (the CI smoke job)

use llm42::engine::{
    Engine, EngineConfig, Mode, PolicyKind, Request, StepKind,
};
use llm42::runtime::Runtime;
use llm42::engine::sampler::sample;
use llm42::util::json::Json;
use llm42::util::rng::SplitMix64;
use llm42::util::stats::{Recorder, Table};

fn reduced() -> bool {
    std::env::var("LLM42_BENCH_REDUCED").map_or(false, |v| v != "0" && !v.is_empty())
}

/// Write the collected sections to `BENCH_engine.json`. Cargo runs bench
/// binaries with the package root (`rust/`) as cwd, so the repo root is
/// one level up; `LLM42_BENCH_JSON` overrides.
fn write_bench_json(sections: Vec<(&str, Json)>) {
    let path = std::env::var("LLM42_BENCH_JSON").unwrap_or_else(|_| {
        if std::path::Path::new("../Makefile").exists() {
            "../BENCH_engine.json".into()
        } else {
            "BENCH_engine.json".into()
        }
    });
    let mut all = vec![
        ("schema", Json::num(1.0)),
        ("reduced", Json::Bool(reduced())),
    ];
    all.extend(sections);
    match std::fs::write(&path, Json::obj(all).dump()) {
        Ok(()) => println!("wrote {path}"),
        Err(e) => eprintln!("could not write {path}: {e}"),
    }
}

fn main() {
    let artifacts =
        std::env::var("LLM42_ARTIFACTS").unwrap_or_else(|_| "artifacts".into());
    let _ = llm42::aot::ensure(&artifacts);
    let mut rt = match Runtime::load(&artifacts) {
        Ok(rt) => rt,
        Err(e) => {
            eprintln!("bench skipped: {e}");
            return;
        }
    };
    let dims = rt.dims().clone();
    let trash = (dims.slots - 1) as i32;
    let reps = if reduced() { 3 } else { 20 };

    // ---- forward passes ---------------------------------------------------
    let mut tab = Table::new(&["pass", "avg_ms", "per_token_us"]);
    let mut fwd = |rt: &mut Runtime, name: &str, g: usize, t: usize, tab: &mut Table| {
        let tokens = vec![3i32; g * t];
        let slots = vec![trash; g];
        let pos = vec![0i32; g];
        if rt.manifest.artifact(name).is_none() {
            return;
        }
        rt.forward(name, &tokens, &slots, &pos).unwrap();
        let t0 = std::time::Instant::now();
        for _ in 0..reps {
            rt.forward(name, &tokens, &slots, &pos).unwrap();
            rt.extract_logits(g * t).unwrap();
        }
        let avg = t0.elapsed().as_secs_f64() / reps as f64;
        tab.row(vec![
            name.to_string(),
            format!("{:.2}", avg * 1e3),
            format!("{:.1}", avg / (g * t) as f64 * 1e6),
        ]);
    };
    for b in [1usize, 4, 16] {
        fwd(&mut rt, &format!("decode_fast_b{b}"), b, 1, &mut tab);
        fwd(&mut rt, &format!("decode_inv_b{b}"), b, 1, &mut tab);
    }
    fwd(&mut rt, "window_inv_g1_t64", 1, 64, &mut tab); // prefill chunk
    fwd(&mut rt, "window_inv_g8_t32", 8, 32, &mut tab); // grouped verify
    println!("{}", tab.render());

    // ---- pure-rust hot pieces ----------------------------------------------
    let mut rng = SplitMix64::new(1);
    let vocab = dims.vocab;
    let logits: Vec<f32> = (0..vocab).map(|_| rng.normal() as f32).collect();
    let mut tab = Table::new(&["component", "ns_per_call", "calls_per_decode_step"]);

    let t0 = std::time::Instant::now();
    let iters = 2000u64;
    let mut sink = 0u32;
    for i in 0..iters {
        sink ^= sample(&logits, 1.0, 42, i);
    }
    let per = t0.elapsed().as_nanos() as f64 / iters as f64;
    tab.row(vec![
        "sampler (gumbel, V=2048)".into(),
        format!("{per:.0}"),
        "1 per lane".into(),
    ]);

    let t0 = std::time::Instant::now();
    for i in 0..iters {
        sink ^= sample(&logits, 0.0, 42, i);
    }
    let per = t0.elapsed().as_nanos() as f64 / iters as f64;
    tab.row(vec![
        "sampler (greedy, V=2048)".into(),
        format!("{per:.0}"),
        "1 per lane".into(),
    ]);
    std::hint::black_box(sink);
    println!("{}", tab.render());
    println!(
        "note: sampler cost per 16-lane decode step ≈ {:.2} ms vs ~25 ms forward — \
         L3 is not the bottleneck (DESIGN.md §9 target)",
        16.0 * per / 1e6
    );

    let mut sections: Vec<(&str, Json)> = Vec::new();
    // the machine's default simulator worker count (LLM42_THREADS env or
    // available parallelism) — the setting every non-sweep section ran at
    sections.push(("threads", Json::num(rt.sim_threads() as f64)));
    if let Some(j) = policy_comparison(&mut rt) {
        sections.push(("policy_comparison", j));
    }
    if let Some(j) = multiturn_cache_comparison(&mut rt) {
        sections.push(("multiturn_cache", j));
    }
    if let Some(j) = fusion_comparison(&mut rt) {
        sections.push(("fusion", j));
    }
    if let Some(j) = verify_policy_comparison(&mut rt) {
        sections.push(("verify_policy", j));
    }
    if let Some(j) = streaming_ttft(&mut rt) {
        sections.push(("streaming", j));
    }
    if let Some(j) = churn(&mut rt) {
        sections.push(("churn", j));
    }
    if let Some(j) = parallel_scaling(&mut rt) {
        sections.push(("parallel", j));
    }
    if let Some(j) = observability_overhead(&mut rt) {
        sections.push(("observability", j));
    }
    if let Some(j) = tp_comparison() {
        sections.push(("tp", j));
    }
    if let Some(j) = router_comparison(dims.vocab) {
        sections.push(("router", j));
    }
    write_bench_json(sections);
}

/// Multi-replica router benchmark: the identical deterministic multi-turn
/// workload (sessions sharing a 32-token prefix, submitted in turn waves
/// from one thread) through a 1-, 2-, and 4-replica fleet over the same
/// baked artifacts. Reports tok/s, the prefix-affinity hit rate, and the
/// shed counter per row; the fleet digest column must be identical at
/// every replica count (asserted) — under single-threaded submission the
/// global ids are a pure function of submission order, so replica count
/// is a deployment shape, not part of the reproducible configuration. A
/// final backpressure row bursts a 2-replica fleet with a 2-deep
/// admission queue: the overflow must shed with `overloaded` instead of
/// queueing without bound.
fn router_comparison(vocab: usize) -> Option<Json> {
    use llm42::obs::digest_hex;
    use llm42::router::{ConnEvent, Router};
    use llm42::tokenizer::Tokenizer;
    use std::sync::{mpsc, Arc};

    let artifacts =
        std::env::var("LLM42_ARTIFACTS").unwrap_or_else(|_| "artifacts".into());
    let tok = match Tokenizer::default_trained(vocab) {
        Ok(t) => Arc::new(t),
        Err(e) => {
            eprintln!("router bench skipped: {e}");
            return None;
        }
    };
    let sessions = if reduced() { 4 } else { 12 };
    let turns = if reduced() { 3 } else { 8 };

    // one session turn: the shared 32-token prefix (two complete KV
    // blocks — what the affinity table keys on) plus a short turn tail
    let prompt = |s: usize, turn: usize| -> Vec<u32> {
        let mut p: Vec<u32> =
            (0..32).map(|i| 3 + ((s * 37 + i) as u32 % 400)).collect();
        for k in 0..4usize {
            p.push(3 + ((turn * 13 + k) as u32 % 400));
        }
        p
    };

    // drain one reply channel to its Done line; (committed tokens, shed?)
    let drain = |rx: &mpsc::Receiver<ConnEvent>| -> Option<(usize, bool)> {
        loop {
            match rx.recv().ok()? {
                ConnEvent::Done(line) => {
                    let v = Json::parse(&line).ok()?;
                    if v.get("error").is_some() {
                        eprintln!("router bench request failed: {line}");
                        return None;
                    }
                    let shed = v.s("finish_reason").ok()? == "overloaded";
                    return Some((v.arr("tokens").ok()?.len(), shed));
                }
                ConnEvent::Accepted(_) | ConnEvent::Line(_) => {}
            }
        }
    };

    let run = |replicas: usize| -> Option<(f64, u64, f64, u64, String)> {
        let cfg = EngineConfig {
            mode: Mode::Llm42,
            verify_group: 2,
            verify_window: 16,
            max_stall_steps: 4,
            eos_token: u32::MAX, // full budgets: identical committed volume
            max_step_tokens: 128,
            prefix_cache: true,
            replicas,
            router_queue: 1024, // ample: this matrix never sheds
            ..Default::default()
        };
        let router = Router::new(&artifacts, &cfg, tok.clone());
        let t0 = llm42::util::now_secs();
        let mut tokens = 0usize;
        for turn in 0..turns {
            let mut rxs = Vec::with_capacity(sessions);
            for s in 0..sessions {
                let (tx, rx) = mpsc::channel();
                router.submit(
                    Request {
                        prompt: prompt(s, turn),
                        max_new_tokens: 8,
                        deterministic: true,
                        temperature: 1.0,
                        seed: (turn * sessions + s) as u64,
                        ..Default::default()
                    },
                    tx,
                );
                rxs.push(rx);
            }
            for rx in &rxs {
                tokens += drain(rx)?.0;
            }
        }
        let wall = llm42::util::now_secs() - t0;
        let c = router.counters();
        router.join();
        Some((
            tokens as f64 / wall.max(1e-9),
            c.routed,
            c.affinity_hits as f64 / (c.routed as f64).max(1.0),
            c.shed,
            digest_hex(c.fleet_digest),
        ))
    };

    let mut tab = Table::new(&[
        "replicas",
        "tok_s",
        "affinity_hit_%",
        "shed",
        "fleet_digest",
    ]);
    let mut rows: Vec<Json> = Vec::new();
    let mut base_digest = String::new();
    for replicas in [1usize, 2, 4] {
        let (tok_s, routed, hit_rate, shed, digest) = run(replicas)?;
        if replicas == 1 {
            base_digest = digest.clone();
        }
        assert_eq!(
            digest, base_digest,
            "router bench: fleet digest diverged at {replicas} replicas"
        );
        tab.row(vec![
            format!("{replicas}"),
            format!("{tok_s:.1}"),
            format!("{:.0}", hit_rate * 100.0),
            format!("{shed}"),
            digest.clone(),
        ]);
        rows.push(Json::obj(vec![
            ("replicas", Json::num(replicas as f64)),
            ("tok_s", Json::num(tok_s)),
            ("routed", Json::num(routed as f64)),
            ("affinity_hit_rate", Json::num(hit_rate)),
            ("shed", Json::num(shed as f64)),
            ("fleet_digest", Json::str(digest)),
        ]));
    }

    // backpressure: burst a 2-replica fleet with a 2-deep admission queue
    // — once each replica holds a long decode, further priority-0 arrivals
    // shed immediately with `overloaded` instead of queueing without bound
    let burst = {
        let cfg = EngineConfig {
            mode: Mode::Llm42,
            verify_group: 2,
            verify_window: 16,
            max_stall_steps: 4,
            eos_token: u32::MAX,
            max_step_tokens: 128,
            replicas: 2,
            router_queue: 2,
            router_affinity: false,
            ..Default::default()
        };
        let router = Router::new(&artifacts, &cfg, tok.clone());
        let n_burst = 10usize;
        let mut rxs = Vec::with_capacity(n_burst);
        for i in 0..n_burst {
            let (tx, rx) = mpsc::channel();
            router.submit(
                Request {
                    prompt: (0..32)
                        .map(|p| 3 + ((p + i as u32 * 13) % 400))
                        .collect(),
                    max_new_tokens: 64,
                    deterministic: false,
                    temperature: 0.0,
                    ..Default::default()
                },
                tx,
            );
            rxs.push(rx);
        }
        let mut served = 0usize;
        let mut shed = 0usize;
        for rx in &rxs {
            let (_, overloaded) = drain(rx)?;
            if overloaded {
                shed += 1;
            } else {
                served += 1;
            }
        }
        router.join();
        println!(
            "burst of {n_burst} at router_queue=2 x 2 replicas: \
             {served} served, {shed} shed"
        );
        Json::obj(vec![
            ("burst", Json::num(n_burst as f64)),
            ("router_queue", Json::num(2.0)),
            ("served", Json::num(served as f64)),
            ("shed", Json::num(shed as f64)),
        ])
    };

    println!("== multi-replica router: 1/2/4 replicas ==");
    println!("{}", tab.render());
    Some(Json::obj(vec![
        ("rows", Json::Arr(rows)),
        ("backpressure", burst),
    ]))
}

/// Tensor-parallel benchmark: the identical fused deterministic workload
/// on sharded artifact sets at R = 1, 2, 4 under the tree collective
/// (its own test-preset sets — `aot::ensure_tp` — so rows are comparable
/// across R). Reports tok/s, the allreduce count, and allreduces per
/// committed token — the TP overhead signal (the simulator executes
/// ranks on one host, so wall-clock rows chart combine overhead, not
/// real interconnect cost). The engine digest column must be identical
/// at every R (asserted): rank count is a deployment shape, not part of
/// the reproducible configuration.
fn tp_comparison() -> Option<Json> {
    use llm42::obs::digest_hex;
    let base =
        std::env::var("LLM42_ARTIFACTS").unwrap_or_else(|_| "artifacts".into());
    let n_reqs = if reduced() { 4 } else { 12 };
    let run = |degree: usize| -> Option<(f64, u64, u64, String)> {
        let dir = format!("{base}-tp{degree}-tree");
        if let Err(e) = llm42::aot::ensure_tp(&dir, degree, "tree") {
            eprintln!("tp bench skipped: {e}");
            return None;
        }
        let mut rt = match Runtime::load(&dir) {
            Ok(rt) => rt,
            Err(e) => {
                eprintln!("tp bench skipped: {e}");
                return None;
            }
        };
        let cfg = EngineConfig {
            mode: Mode::Llm42,
            verify_group: 2,
            verify_window: 16,
            max_stall_steps: 4,
            eos_token: u32::MAX, // full budgets: identical committed volume
            max_step_tokens: 128,
            ..Default::default()
        };
        let mut eng = match Engine::new(&mut rt, cfg) {
            Ok(e) => e,
            Err(e) => {
                eprintln!("tp bench skipped: {e}");
                return None;
            }
        };
        let _ = eng.warmup();
        for i in 0..n_reqs {
            eng.submit(Request {
                prompt: (0..96).map(|p| 3 + ((p + i as u32 * 13) % 400)).collect(),
                max_new_tokens: 12,
                deterministic: true,
                temperature: 1.0,
                seed: 100_000 + i as u64,
                ..Default::default()
            })
            .unwrap();
        }
        let t0 = llm42::util::now_secs();
        if let Err(e) = eng.run_to_completion() {
            eprintln!("tp bench aborted: {e}");
            return None;
        }
        let wall = llm42::util::now_secs() - t0;
        eng.take_finished();
        Some((
            eng.metrics.committed_tokens as f64 / wall.max(1e-9),
            eng.metrics.committed_tokens,
            eng.metrics.tp_allreduces,
            digest_hex(eng.obs.engine_digest()),
        ))
    };
    let mut tab = Table::new(&[
        "tp_degree",
        "tok_s",
        "allreduces",
        "allreduce_per_tok",
        "engine_digest",
    ]);
    let mut rows: Vec<Json> = Vec::new();
    let mut base_digest = String::new();
    for degree in [1usize, 2, 4] {
        let (tok_s, committed, allreduces, digest) = run(degree)?;
        if degree == 1 {
            base_digest = digest.clone();
        }
        assert_eq!(
            digest, base_digest,
            "tp bench: engine digest diverged at R={degree} (tree collective)"
        );
        let per_tok = allreduces as f64 / (committed as f64).max(1.0);
        tab.row(vec![
            format!("{degree}"),
            format!("{tok_s:.1}"),
            format!("{allreduces}"),
            format!("{per_tok:.1}"),
            digest.clone(),
        ]);
        rows.push(Json::obj(vec![
            ("tp_degree", Json::num(degree as f64)),
            ("collective", Json::str("tree")),
            ("tok_s", Json::num(tok_s)),
            ("allreduces", Json::num(allreduces as f64)),
            ("allreduce_per_committed_token", Json::num(per_tok)),
            ("engine_digest", Json::str(digest)),
        ]));
    }
    println!("== tensor parallel: R=1/2/4, tree collective ==");
    println!("{}", tab.render());
    Some(Json::Arr(rows))
}

/// Observability overhead: the identical deterministic steady workload at
/// obs `off` vs `events` (journal, per-row verify margins, histograms all
/// live). Acceptance: `events` costs < 3% tok/s vs `off`; the engine
/// digest column must be identical in both rows — recording never changes
/// committed streams.
fn observability_overhead(rt: &mut Runtime) -> Option<Json> {
    use llm42::obs::{digest_hex, ObsConfig, ObsLevel};
    let n_reqs = if reduced() { 6 } else { 16 };
    let run = |rt: &mut Runtime, level: ObsLevel| -> Option<(f64, u64, String)> {
        let cfg = EngineConfig {
            mode: Mode::Llm42,
            verify_group: 2,
            verify_window: 16,
            max_stall_steps: 4,
            eos_token: u32::MAX, // full budgets: identical committed volume
            max_step_tokens: 128,
            obs: ObsConfig { level, ..Default::default() },
            ..Default::default()
        };
        let mut eng = match Engine::new(rt, cfg) {
            Ok(e) => e,
            Err(e) => {
                eprintln!("observability bench skipped: {e}");
                return None;
            }
        };
        let _ = eng.warmup();
        for i in 0..n_reqs {
            eng.submit(Request {
                prompt: (0..100).map(|p| 3 + ((p + i as u32 * 13) % 400)).collect(),
                max_new_tokens: 16,
                deterministic: true,
                temperature: 1.0,
                seed: 60_000 + i as u64,
                ..Default::default()
            })
            .unwrap();
        }
        let t0 = llm42::util::now_secs();
        if let Err(e) = eng.run_to_completion() {
            eprintln!("observability bench aborted: {e}");
            return None;
        }
        let wall = llm42::util::now_secs() - t0;
        eng.take_finished();
        Some((
            eng.metrics.committed_tokens as f64 / wall.max(1e-9),
            eng.obs.last_seq(),
            digest_hex(eng.obs.engine_digest()),
        ))
    };
    let mut tab =
        Table::new(&["obs", "tok_s", "overhead_%", "events", "engine_digest"]);
    let mut rows: Vec<Json> = Vec::new();
    let mut base = 0.0f64;
    for level in [ObsLevel::Off, ObsLevel::Events] {
        let (tok_s, events, digest) = run(rt, level)?;
        if level == ObsLevel::Off {
            base = tok_s;
        }
        let overhead_pct =
            if base > 0.0 { (1.0 - tok_s / base) * 100.0 } else { 0.0 };
        tab.row(vec![
            level.as_str().to_string(),
            format!("{tok_s:.1}"),
            format!("{overhead_pct:.1}"),
            format!("{events}"),
            digest.clone(),
        ]);
        rows.push(Json::obj(vec![
            ("obs", Json::str(level.as_str())),
            ("tok_s", Json::num(tok_s)),
            ("overhead_pct", Json::num(overhead_pct)),
            ("events", Json::num(events as f64)),
            ("engine_digest", Json::str(digest)),
        ]));
    }
    println!("== observability: recording overhead off vs events ==");
    println!("{}", tab.render());
    Some(Json::Arr(rows))
}

/// Thread-scaling sweep: the identical workloads at 1/2/4/8 simulator
/// worker threads. Committed streams are bitwise identical at every row
/// (`tests/parallel.rs` pins that), so this table records only what the
/// knob buys: steady-state tok/s on a fused prefill-heavy mixed workload,
/// churn tok/s on the short-request closed-loop shape, scaling vs the
/// 1-thread row (with per-thread efficiency), and the engine's measured
/// worker-busy fraction.
fn parallel_scaling(rt: &mut Runtime) -> Option<Json> {
    let n_reqs = if reduced() { 4 } else { 12 };
    let churn_total = if reduced() { 120usize } else { 1_000 };

    // steady state: long prompts + decode population, step composer on
    let steady = |rt: &mut Runtime, threads: usize| -> Option<(f64, f64)> {
        let cfg = EngineConfig {
            mode: Mode::Llm42,
            verify_group: 2,
            verify_window: 16,
            max_stall_steps: 4,
            eos_token: u32::MAX, // full budgets: identical committed volume
            max_step_tokens: 128,
            threads,
            ..Default::default()
        };
        let mut eng = match Engine::new(rt, cfg) {
            Ok(e) => e,
            Err(e) => {
                eprintln!("parallel bench skipped: {e}");
                return None;
            }
        };
        let _ = eng.warmup();
        for i in 0..n_reqs {
            eng.submit(Request {
                prompt: (0..100).map(|p| 3 + ((p + i as u32 * 13) % 400)).collect(),
                max_new_tokens: 16,
                deterministic: i % 4 == 0,
                temperature: 1.0,
                seed: 50_000 + i as u64,
                ..Default::default()
            })
            .unwrap();
        }
        let t0 = llm42::util::now_secs();
        if let Err(e) = eng.run_to_completion() {
            eprintln!("parallel bench aborted: {e}");
            return None;
        }
        let wall = llm42::util::now_secs() - t0;
        eng.take_finished();
        Some((
            eng.metrics.committed_tokens as f64 / wall.max(1e-9),
            eng.metrics.parallel_efficiency(),
        ))
    };

    // churn: the short-request closed loop from the churn section
    let churn_rate = |rt: &mut Runtime, threads: usize| -> Option<f64> {
        let cfg = EngineConfig {
            mode: Mode::NonDeterministic,
            eos_token: u32::MAX,
            threads,
            ..Default::default()
        };
        let mut eng = match Engine::new(rt, cfg) {
            Ok(e) => e,
            Err(e) => {
                eprintln!("parallel bench skipped: {e}");
                return None;
            }
        };
        let _ = eng.warmup();
        let wave = 8usize;
        let mut submitted = 0usize;
        let t0 = llm42::util::now_secs();
        while submitted < churn_total {
            let n = wave.min(churn_total - submitted);
            for i in 0..n {
                let t = 3 + ((submitted + i) as u32 % 300);
                let ok = eng.submit(Request {
                    prompt: vec![t; 8],
                    max_new_tokens: 2,
                    deterministic: false,
                    temperature: 0.0,
                    seed: 0,
                    ..Default::default()
                });
                if let Err(e) = ok {
                    eprintln!("parallel bench aborted: {e}");
                    return None;
                }
            }
            submitted += n;
            if let Err(e) = eng.run_to_completion() {
                eprintln!("parallel bench aborted: {e}");
                return None;
            }
            eng.take_finished();
        }
        let wall = llm42::util::now_secs() - t0;
        Some(eng.metrics.committed_tokens as f64 / wall.max(1e-9))
    };

    let mut tab = Table::new(&[
        "threads",
        "steady_tok_s",
        "churn_tok_s",
        "scaling_x",
        "efficiency_%",
        "busy_frac_%",
    ]);
    let mut rows: Vec<Json> = Vec::new();
    let mut base_steady = 0.0f64;
    for threads in [1usize, 2, 4, 8] {
        let (Some((steady_tok_s, busy_frac)), Some(churn_tok_s)) =
            (steady(rt, threads), churn_rate(rt, threads))
        else {
            rt.set_sim_threads(0);
            return None;
        };
        if threads == 1 {
            base_steady = steady_tok_s;
        }
        let scaling = steady_tok_s / base_steady.max(1e-9);
        let efficiency = scaling / threads as f64;
        tab.row(vec![
            format!("{threads}"),
            format!("{steady_tok_s:.1}"),
            format!("{churn_tok_s:.1}"),
            format!("{scaling:.2}"),
            format!("{:.0}", efficiency * 100.0),
            format!("{:.0}", busy_frac * 100.0),
        ]);
        rows.push(Json::obj(vec![
            ("threads", Json::num(threads as f64)),
            ("steady_tok_s", Json::num(steady_tok_s)),
            ("churn_tok_s", Json::num(churn_tok_s)),
            ("scaling_x", Json::num(scaling)),
            ("scaling_efficiency", Json::num(efficiency)),
            ("parallel_efficiency", Json::num(busy_frac)),
        ]));
    }
    rt.set_sim_threads(0);
    println!("== thread scaling: 1/2/4/8 simulator workers ==");
    println!("{}", tab.render());
    Some(Json::Arr(rows))
}

/// Request-churn soak: a closed loop of short requests, an order of
/// magnitude more than the engine ever holds live. Reports steady-state
/// throughput over the early window (first 10% of requests) vs the late
/// window (the rest) plus the sequence-store occupancy gauges. The
/// pre-store engine scanned a tombstone per finished request every step,
/// so its late-window tok/s degraded with cumulative traffic; with the
/// slab store the two columns must stay flat and `store_capacity` must
/// track the live high-water mark, not the request count.
fn churn(rt: &mut Runtime) -> Option<Json> {
    let total = if reduced() { 1_000usize } else { 10_000 };
    let early_at = total / 10; // "at request 1k" in the full run
    let wave = 8usize;
    let cfg = EngineConfig {
        mode: Mode::NonDeterministic,
        eos_token: u32::MAX, // full budgets: identical request shapes
        ..Default::default()
    };
    let mut eng = match Engine::new(rt, cfg) {
        Ok(e) => e,
        Err(e) => {
            eprintln!("churn bench skipped: {e}");
            return None;
        }
    };
    let _ = eng.warmup();
    let t0 = llm42::util::now_secs();
    let mut submitted = 0usize;
    let mut done = 0usize;
    let mut early: Option<(f64, u64)> = None; // (wall_s, committed) at early_at
    while done < total {
        let n = wave.min(total - submitted);
        for i in 0..n {
            let t = 3 + ((submitted + i) as u32 % 300);
            let ok = eng.submit(Request {
                prompt: vec![t; 8],
                max_new_tokens: 2,
                deterministic: false,
                temperature: 0.0,
                seed: 0,
                ..Default::default()
            });
            if let Err(e) = ok {
                eprintln!("churn bench aborted: {e}");
                return None;
            }
        }
        submitted += n;
        if let Err(e) = eng.run_to_completion() {
            eprintln!("churn bench aborted: {e}");
            return None;
        }
        done += eng.take_finished().len();
        if early.is_none() && done >= early_at {
            early = Some((
                llm42::util::now_secs() - t0,
                eng.metrics.committed_tokens,
            ));
        }
    }
    let wall = llm42::util::now_secs() - t0;
    let (early_wall, early_tok) = early.unwrap_or((wall, eng.metrics.committed_tokens));
    let late_tok = eng.metrics.committed_tokens - early_tok;
    let tok_s_early = early_tok as f64 / early_wall.max(1e-9);
    let tok_s_late = late_tok as f64 / (wall - early_wall).max(1e-9);
    let mut tab = Table::new(&[
        "requests",
        "tok_s_early",
        "tok_s_late",
        "store_capacity",
        "live_hwm",
        "steps",
    ]);
    tab.row(vec![
        format!("{total}"),
        format!("{tok_s_early:.0}"),
        format!("{tok_s_late:.0}"),
        format!("{}", eng.metrics.store_capacity),
        format!("{}", eng.metrics.live_seqs_hwm),
        format!("{}", eng.metrics.steps),
    ]);
    println!("== request churn: steady-state throughput early vs late ==");
    println!("{}", tab.render());
    Some(Json::obj(vec![
        ("requests", Json::num(total as f64)),
        ("early_at_requests", Json::num(early_at as f64)),
        ("tok_s_early", Json::num(tok_s_early)),
        ("tok_s_late", Json::num(tok_s_late)),
        ("store_capacity", Json::num(eng.metrics.store_capacity as f64)),
        ("live_seqs_hwm", Json::num(eng.metrics.live_seqs_hwm as f64)),
        ("steps", Json::num(eng.metrics.steps as f64)),
        ("wall_s", Json::num(wall)),
    ]))
}

/// Streamed time-to-first-token: the latency until a request's first
/// *committed* token is available as a stream delta — what a streaming
/// client actually perceives as TTFT. Under LLM-42 only committed tokens
/// may be surfaced (speculative ones can roll back), so this is the honest
/// streaming latency; for DVR-deterministic traffic gen token 0 commits at
/// prefill, so streamed TTFT tracks the engine's internal TTFT rather than
/// trailing it by a verification window.
fn streaming_ttft(rt: &mut Runtime) -> Option<Json> {
    use std::collections::HashMap;
    let n = if reduced() { 6 } else { 16 };
    let cfg = EngineConfig {
        mode: Mode::Llm42,
        verify_group: 2,
        verify_window: 16,
        max_stall_steps: 4,
        eos_token: u32::MAX, // full budgets: stable shape
        ..Default::default()
    };
    let mut eng = match Engine::new(rt, cfg) {
        Ok(e) => e,
        Err(e) => {
            eprintln!("streaming bench skipped: {e}");
            return None;
        }
    };
    let _ = eng.warmup();
    let mut submitted: HashMap<u64, f64> = HashMap::new();
    for i in 0..n {
        let id = eng
            .submit(Request {
                prompt: (0..48).map(|p| 3 + ((p + i as u32 * 11) % 400)).collect(),
                max_new_tokens: 24,
                deterministic: i % 2 == 0,
                temperature: 1.0,
                seed: 70_000 + i as u64,
                stream: true,
                ..Default::default()
            })
            .unwrap();
        submitted.insert(id, llm42::util::now_secs());
    }
    let mut first_delta: HashMap<u64, f64> = HashMap::new();
    let mut streamed_tokens: HashMap<u64, u64> = HashMap::new();
    while !eng.idle() {
        if let Err(e) = eng.step() {
            eprintln!("streaming bench aborted: {e}");
            return None;
        }
        let now = llm42::util::now_secs();
        for d in eng.take_stream_deltas() {
            first_delta.entry(d.id).or_insert(now - submitted[&d.id]);
            *streamed_tokens.entry(d.id).or_insert(0) += d.tokens.len() as u64;
        }
    }
    let outs = eng.take_finished();
    let mut stream_ttft = Recorder::new();
    let mut engine_ttft = Recorder::new();
    for o in &outs {
        stream_ttft.record(first_delta[&o.id] * 1e3);
        if let Some(t) = o.metrics.ttft() {
            engine_ttft.record(t * 1e3);
        }
        assert_eq!(
            streamed_tokens[&o.id],
            o.tokens.len() as u64,
            "stream deltas must cover the full output"
        );
    }
    let mut tab = Table::new(&[
        "requests",
        "streamed_ttft_p50_ms",
        "streamed_ttft_p99_ms",
        "engine_ttft_p50_ms",
        "engine_ttft_p99_ms",
    ]);
    tab.row(vec![
        format!("{n}"),
        format!("{:.1}", stream_ttft.percentile(50.0)),
        format!("{:.1}", stream_ttft.percentile(99.0)),
        format!("{:.1}", engine_ttft.percentile(50.0)),
        format!("{:.1}", engine_ttft.percentile(99.0)),
    ]);
    println!("== commit-boundary streaming: time to first committed token ==");
    println!("{}", tab.render());
    Some(Json::obj(vec![
        ("requests", Json::num(n as f64)),
        ("streamed_ttft_p50_ms", Json::num(stream_ttft.percentile(50.0))),
        ("streamed_ttft_p99_ms", Json::num(stream_ttft.percentile(99.0))),
        ("engine_ttft_p50_ms", Json::num(engine_ttft.percentile(50.0))),
        ("engine_ttft_p99_ms", Json::num(engine_ttft.percentile(99.0))),
    ]))
}

/// Step-composer benchmark: the same prefill-heavy mixed workload (long
/// prompts head-of-line-blocking a decode population, plus deterministic
/// traffic in the middle) with fusion off vs on at equal `max_batch`.
/// Headline column: forwards per committed token — the acceptance
/// criterion is a >= 25% reduction with fusion on.
fn fusion_comparison(rt: &mut Runtime) -> Option<Json> {
    let n_reqs = if reduced() { 6 } else { 16 };
    let mut tab = Table::new(&[
        "max_step_tokens",
        "fwd/tok",
        "forwards",
        "tok_s",
        "ttft_p50_ms",
        "ttft_p99_ms",
        "det_e2e_p99_ms",
        "fused_occ_%",
    ]);
    let mut rows: Vec<Json> = Vec::new();
    for budget in [0usize, 128] {
        let cfg = EngineConfig {
            mode: Mode::Llm42,
            verify_group: 2,
            verify_window: 16,
            max_stall_steps: 4,
            eos_token: u32::MAX, // full budgets: identical committed volume
            max_step_tokens: budget,
            ..Default::default()
        };
        let mut eng = match Engine::new(rt, cfg) {
            Ok(e) => e,
            Err(e) => {
                eprintln!("fusion bench skipped: {e}");
                return None;
            }
        };
        let _ = eng.warmup();
        // arxiv-ish shape: long prompts, short outputs, 25% deterministic
        for i in 0..n_reqs {
            eng.submit(Request {
                prompt: (0..100).map(|p| 3 + ((p + i as u32 * 13) % 400)).collect(),
                max_new_tokens: 10,
                deterministic: i % 4 == 0,
                temperature: 1.0,
                seed: 90_000 + i as u64,
                priority: 0,
                deadline_ms: None,
                ..Default::default()
            })
            .unwrap();
        }
        let t0 = llm42::util::now_secs();
        if let Err(e) = eng.run_to_completion() {
            eprintln!("fusion bench aborted: {e}");
            return None;
        }
        let wall = llm42::util::now_secs() - t0;
        let outs = eng.take_finished();
        let mut ttft = Recorder::new();
        let mut det_e2e = Recorder::new();
        for o in &outs {
            if let Some(t) = o.metrics.ttft() {
                ttft.record(t * 1e3);
            }
            if o.deterministic {
                det_e2e.record(o.metrics.e2e() * 1e3);
            }
        }
        let m = &eng.metrics;
        let fwd_per_tok = m.forwards_per_committed_token();
        tab.row(vec![
            format!("{budget}"),
            format!("{fwd_per_tok:.3}"),
            format!("{}", m.forward_passes),
            format!("{:.1}", m.committed_tokens as f64 / wall.max(1e-9)),
            format!("{:.0}", ttft.percentile(50.0)),
            format!("{:.0}", ttft.percentile(99.0)),
            format!("{:.0}", det_e2e.percentile(99.0)),
            format!("{:.0}", m.fused_occupancy() * 100.0),
        ]);
        rows.push(Json::obj(vec![
            ("max_step_tokens", Json::num(budget as f64)),
            ("forwards_per_committed_token", Json::num(fwd_per_tok)),
            ("forward_passes", Json::num(m.forward_passes as f64)),
            ("committed_tokens", Json::num(m.committed_tokens as f64)),
            (
                "tok_s",
                Json::num(m.committed_tokens as f64 / wall.max(1e-9)),
            ),
            ("ttft_p50_ms", Json::num(ttft.percentile(50.0))),
            ("ttft_p99_ms", Json::num(ttft.percentile(99.0))),
            ("det_e2e_p99_ms", Json::num(det_e2e.percentile(99.0))),
            ("fused_occupancy", Json::num(m.fused_occupancy())),
            ("wall_s", Json::num(wall)),
        ]));
    }
    println!("== step composer: fusion off vs on ==");
    println!("{}", tab.render());
    Some(Json::Arr(rows))
}

/// Margin-gate benchmark: the same fused all-deterministic workload with
/// the verify trigger at `stall` (gate off, the fused baseline) vs
/// `margin-gate` (gate on), on two traffic shapes. `wide_margin` is greedy
/// traffic against the calibrated per-artifact bound — most tokens carry a
/// certificate and skip the verify window, so the acceptance criterion is
/// forwards per committed token strictly below the fused baseline with
/// tok/s improving. `adversarial` models traffic where no margin clears
/// the bound (`margin_bound_override = +inf`: nothing ever certifies) —
/// the gate must cost nothing there, matching the baseline's forward
/// count. Both shapes are deterministic-only, so the engine digest column
/// must be identical gate off vs on (asserted): certificates change how
/// much verification work runs, never what commits.
fn verify_policy_comparison(rt: &mut Runtime) -> Option<Json> {
    use llm42::engine::{VerifyPolicy, VerifyPolicyKind};
    use llm42::obs::digest_hex;

    struct GateRun {
        name: &'static str,
        fwd_per_tok: f64,
        forward_passes: u64,
        verify_passes: u64,
        tok_s: f64,
        certified: u64,
        verified: u64,
        repair: u64,
        digest: u64,
        wall: f64,
    }
    impl GateRun {
        fn json(&self) -> Json {
            Json::obj(vec![
                ("gate", Json::str(self.name)),
                ("forwards_per_committed_token", Json::num(self.fwd_per_tok)),
                ("forward_passes", Json::num(self.forward_passes as f64)),
                ("verify_passes", Json::num(self.verify_passes as f64)),
                ("tok_s", Json::num(self.tok_s)),
                ("certified_tokens", Json::num(self.certified as f64)),
                ("verified_tokens", Json::num(self.verified as f64)),
                ("gate_repair_tokens", Json::num(self.repair as f64)),
                ("engine_digest", Json::str(digest_hex(self.digest))),
                ("wall_s", Json::num(self.wall)),
            ])
        }
    }

    let n_reqs = if reduced() { 6 } else { 16 };
    let run = |rt: &mut Runtime,
               kind: VerifyPolicyKind,
               bound_override: Option<f32>,
               temperature: f32|
     -> Option<GateRun> {
        let cfg = EngineConfig {
            mode: Mode::Llm42,
            verify_group: 2,
            verify_window: 16,
            max_stall_steps: 4,
            eos_token: u32::MAX, // full budgets: identical committed volume
            max_step_tokens: 128,
            verify_policy: VerifyPolicy::new(kind),
            margin_bound_override: bound_override,
            ..Default::default()
        };
        let mut eng = match Engine::new(rt, cfg) {
            Ok(e) => e,
            Err(e) => {
                eprintln!("verify_policy bench skipped: {e}");
                return None;
            }
        };
        let _ = eng.warmup();
        for i in 0..n_reqs {
            eng.submit(Request {
                prompt: (0..100).map(|p| 3 + ((p + i as u32 * 13) % 400)).collect(),
                max_new_tokens: 24,
                deterministic: true,
                temperature,
                seed: 80_000 + i as u64,
                ..Default::default()
            })
            .unwrap();
        }
        let t0 = llm42::util::now_secs();
        if let Err(e) = eng.run_to_completion() {
            eprintln!("verify_policy bench aborted: {e}");
            return None;
        }
        let wall = llm42::util::now_secs() - t0;
        eng.take_finished();
        let m = &eng.metrics;
        Some(GateRun {
            name: VerifyPolicy::new(kind).kind.name(),
            fwd_per_tok: m.forwards_per_committed_token(),
            forward_passes: m.forward_passes,
            verify_passes: m.verify_passes,
            tok_s: m.committed_tokens as f64 / wall.max(1e-9),
            certified: m.certified_tokens,
            verified: m.verified_tokens,
            repair: m.gate_repair_tokens,
            digest: eng.obs.engine_digest(),
            wall,
        })
    };

    let mut tab = Table::new(&[
        "traffic",
        "gate",
        "fwd/tok",
        "tok_s",
        "certified",
        "verified",
        "repair",
    ]);
    let mut rows: Vec<Json> = Vec::new();
    // (traffic, bound override, temperature): wide-margin greedy traffic
    // uses the calibrated manifest bound; adversarial pins +inf so no
    // margin ever clears it
    for (traffic, bound, temp) in [
        ("wide_margin", None, 0.0f32),
        ("adversarial", Some(f32::INFINITY), 1.0),
    ] {
        let off = run(rt, VerifyPolicyKind::Stall, bound, temp)?;
        let on = run(rt, VerifyPolicyKind::MarginGate, bound, temp)?;
        assert_eq!(
            off.digest, on.digest,
            "margin gate changed a committed stream on {traffic} traffic"
        );
        for r in [&off, &on] {
            tab.row(vec![
                traffic.to_string(),
                r.name.to_string(),
                format!("{:.3}", r.fwd_per_tok),
                format!("{:.1}", r.tok_s),
                format!("{}", r.certified),
                format!("{}", r.verified),
                format!("{}", r.repair),
            ]);
        }
        rows.push(Json::obj(vec![
            ("traffic", Json::str(traffic)),
            ("gate_off", off.json()),
            ("gate_on", on.json()),
        ]));
    }
    println!("== verify policy: margin gate off vs on ==");
    println!("{}", tab.render());
    Some(Json::Arr(rows))
}

/// Multi-turn chat, closed loop: every follow-up turn resubmits the
/// committed history (shared system prompt + prior turns), the
/// prefix-cache-heavy workload class. Reports prefill tokens computed vs
/// served from cache and deterministic TTFT with the cache off vs on —
/// the paged-KV acceptance measurement (>= 30% prefill-token reduction
/// from cache hits on this shape).
fn multiturn_cache_comparison(rt: &mut Runtime) -> Option<Json> {
    let mut tab = Table::new(&[
        "prefix_cache",
        "prefill_tok",
        "cache_hit_tok",
        "prefill_saved_%",
        "ttft_p50_ms",
        "ttft_p99_ms",
    ]);
    let mut rows: Vec<Json> = Vec::new();
    let n_convs = 4usize;
    let turns = if reduced() { 2 } else { 5 };
    let mut baseline_prefill = 0u64;
    for cache in [false, true] {
        let cfg = EngineConfig {
            mode: Mode::Llm42,
            verify_group: 4,
            verify_window: 16,
            max_stall_steps: 4,
            eos_token: u32::MAX, // full budgets: identical turn shapes
            prefix_cache: cache,
            ..Default::default()
        };
        let mut eng = match Engine::new(rt, cfg) {
            Ok(e) => e,
            Err(e) => {
                eprintln!("multiturn bench skipped: {e}");
                return None;
            }
        };
        let _ = eng.warmup();

        // identical shared system prompt across every conversation
        let system: Vec<u32> = (40..64).collect();
        let mut histories: Vec<Vec<u32>> = vec![system.clone(); n_convs];
        let mut ttft = Recorder::new();
        for turn in 0..turns {
            let mut wave: Vec<(u64, usize)> = Vec::new();
            for c in 0..n_convs {
                let mut prompt = histories[c].clone();
                for k in 0..6usize {
                    prompt.push(70 + ((turn * 13 + c * 7 + k) as u32 % 300));
                }
                histories[c] = prompt.clone();
                let id = eng
                    .submit(Request {
                        prompt,
                        max_new_tokens: 8,
                        deterministic: true,
                        temperature: 1.0,
                        seed: (turn * n_convs + c) as u64,
                        priority: 0,
                        deadline_ms: None,
                        ..Default::default()
                    })
                    .unwrap();
                wave.push((id, c));
            }
            if let Err(e) = eng.run_to_completion() {
                eprintln!("multiturn bench aborted: {e}");
                return None;
            }
            // closed loop: append each reply's committed tokens to its
            // conversation before the next turn resubmits the history
            let outs = eng.take_finished();
            for (id, c) in wave {
                let o = outs.iter().find(|o| o.id == id).expect("turn finished");
                histories[c].extend(o.tokens.iter().copied());
                if let Some(t) = o.metrics.ttft() {
                    ttft.record(t * 1e3);
                }
            }
        }
        let prefill = eng.metrics.prefill_tokens;
        let hits = eng.metrics.cache_hit_tokens;
        if !cache {
            baseline_prefill = prefill;
        }
        let saved = if cache && baseline_prefill > 0 {
            100.0 * (baseline_prefill.saturating_sub(prefill)) as f64
                / baseline_prefill as f64
        } else {
            0.0
        };
        tab.row(vec![
            format!("{cache}"),
            format!("{prefill}"),
            format!("{hits}"),
            format!("{saved:.0}"),
            format!("{:.0}", ttft.percentile(50.0)),
            format!("{:.0}", ttft.percentile(99.0)),
        ]);
        rows.push(Json::obj(vec![
            ("prefix_cache", Json::Bool(cache)),
            ("prefill_tokens", Json::num(prefill as f64)),
            ("cache_hit_tokens", Json::num(hits as f64)),
            ("prefill_saved_pct", Json::num(saved)),
            ("ttft_p50_ms", Json::num(ttft.percentile(50.0))),
            ("ttft_p99_ms", Json::num(ttft.percentile(99.0))),
        ]));
    }
    println!("== multiturn chat: prefix cache off vs on ==");
    println!("{}", tab.render());
    Some(Json::Arr(rows))
}

/// Mixed-traffic policy benchmark: a handful of high-priority deterministic
/// requests arrive while a saturating low-priority non-deterministic
/// background occupies every KV slot. Reports per-policy p50/p99
/// deterministic e2e plus preemption/re-prefill cost — the scheduler split's
/// acceptance measurement (DeadlineAware/FairShare should cut the
/// deterministic tail vs the seed PrefillFirst policy).
fn policy_comparison(rt: &mut Runtime) -> Option<Json> {
    let user_slots = rt.dims().slots - 1;
    let mut tab = Table::new(&[
        "policy",
        "det_p50_ms",
        "det_p99_ms",
        "bg_p99_ms",
        "preemptions",
        "reprefilled",
        "wall_s",
    ]);
    let mut rows: Vec<Json> = Vec::new();
    for policy in [
        PolicyKind::PrefillFirst,
        PolicyKind::DeadlineAware,
        PolicyKind::FairShare,
    ] {
        let cfg = EngineConfig {
            mode: Mode::Llm42,
            verify_group: 2,
            verify_window: 16,
            max_stall_steps: 4,
            eos_token: u32::MAX, // run full length budgets: stable load
            policy,
            ..Default::default()
        };
        let mut eng = match Engine::new(rt, cfg) {
            Ok(e) => e,
            Err(e) => {
                eprintln!("policy bench skipped: {e}");
                return None;
            }
        };
        let _ = eng.warmup();

        // saturating background: 4x as many low-priority requests as
        // slots, long budgets — keeps every slot contended for the whole
        // deterministic arrival window
        let n_bg = user_slots * if reduced() { 2 } else { 4 };
        for i in 0..n_bg {
            eng.submit(Request {
                prompt: (10..26).map(|t| t + (i as u32 % 7)).collect(),
                max_new_tokens: 96,
                deterministic: false,
                temperature: 1.0,
                seed: 40_000 + i as u64,
                priority: 0,
                deadline_ms: None,
                ..Default::default()
            })
            .unwrap();
        }
        // high-priority deterministic requests arrive once the background
        // is decoding (trickled in as the run progresses); enough samples
        // that the p99 column is a tail estimate, not a single max
        let det_every = 15usize; // steps between deterministic arrivals
        let n_det = if reduced() { 6 } else { 24 };
        let mut det_submitted = 0usize;
        let mut steps = 0usize;
        let t0 = llm42::util::now_secs();
        loop {
            if det_submitted < n_det && steps == det_every * (det_submitted + 1) {
                eng.submit(Request {
                    prompt: (30..42).collect(),
                    max_new_tokens: 16,
                    deterministic: true,
                    temperature: 1.0,
                    seed: 7 + det_submitted as u64,
                    priority: 4,
                    deadline_ms: Some(250.0),
                    ..Default::default()
                })
                .unwrap();
                det_submitted += 1;
            }
            if det_submitted >= n_det && eng.idle() {
                break;
            }
            match eng.step() {
                Ok(StepKind::Idle) => {
                    if det_submitted >= n_det {
                        break;
                    }
                    // waiting for the next scripted arrival
                }
                Ok(_) => {}
                Err(e) => {
                    eprintln!("policy bench aborted: {e}");
                    return None;
                }
            }
            steps += 1;
        }
        let wall = llm42::util::now_secs() - t0;

        let outs = eng.take_finished();
        let mut det_e2e = Recorder::new();
        let mut bg_e2e = Recorder::new();
        for o in &outs {
            if o.deterministic {
                det_e2e.record(o.metrics.e2e() * 1e3);
            } else {
                bg_e2e.record(o.metrics.e2e() * 1e3);
            }
        }
        tab.row(vec![
            eng.policy_name().to_string(),
            format!("{:.0}", det_e2e.percentile(50.0)),
            format!("{:.0}", det_e2e.percentile(99.0)),
            format!("{:.0}", bg_e2e.percentile(99.0)),
            format!("{}", eng.metrics.preemptions),
            format!("{}", eng.metrics.reprefilled_tokens),
            format!("{wall:.1}"),
        ]);
        rows.push(Json::obj(vec![
            ("policy", Json::str(eng.policy_name())),
            ("det_e2e_p50_ms", Json::num(det_e2e.percentile(50.0))),
            ("det_e2e_p99_ms", Json::num(det_e2e.percentile(99.0))),
            ("bg_e2e_p99_ms", Json::num(bg_e2e.percentile(99.0))),
            ("preemptions", Json::num(eng.metrics.preemptions as f64)),
            (
                "reprefilled_tokens",
                Json::num(eng.metrics.reprefilled_tokens as f64),
            ),
            (
                "tok_s",
                Json::num(eng.metrics.committed_tokens as f64 / wall.max(1e-9)),
            ),
            (
                "forwards_per_committed_token",
                Json::num(eng.metrics.forwards_per_committed_token()),
            ),
            ("wall_s", Json::num(wall)),
        ]));
    }
    println!("== mixed traffic: policy comparison ==");
    println!("{}", tab.render());
    Some(Json::Arr(rows))
}
