//! End-to-end throughput bench: one compact offline run per mode
//! (a condensed Fig. 5/10 — the full sweeps live in `llm42 experiments`).
//!
//!     cargo bench --bench e2e

use llm42::engine::{Engine, EngineConfig, Mode};
use llm42::runtime::Runtime;
use llm42::trace::{LengthProfile, TraceSpec};
use llm42::util::now_secs;
use llm42::util::stats::Table;

fn main() {
    let artifacts =
        std::env::var("LLM42_ARTIFACTS").unwrap_or_else(|_| "artifacts".into());
    let _ = llm42::aot::ensure(&artifacts);
    let mut rt = match Runtime::load(&artifacts) {
        Ok(rt) => rt,
        Err(e) => {
            eprintln!("bench skipped: {e}");
            return;
        }
    };
    let dims = rt.dims().clone();
    let spec = |det: f64| TraceSpec {
        profile: LengthProfile::Fixed { name: "bench", input: 32, output: 48 },
        n_requests: 12,
        det_ratio: det,
        qps: None,
        seed: 11,
        temperature: 1.0,
        vocab: dims.vocab,
        max_seq: dims.max_seq,
        window: 32,
    };

    let mut tab = Table::new(&["mode", "out_tok_per_s", "vs_nondet"]);
    let mut base = None;
    for (label, mode, det) in [
        ("non-deterministic", Mode::NonDeterministic, 0.0),
        ("batch-invariant", Mode::BatchInvariant, 0.0),
        ("llm42 @10% det", Mode::Llm42, 0.10),
        ("llm42 @100% det", Mode::Llm42, 1.0),
    ] {
        let cfg = EngineConfig { mode, ..Default::default() };
        let mut eng = Engine::new(&mut rt, cfg).unwrap();
        eng.warmup().unwrap();
        let start = now_secs();
        for tr in spec(det).generate() {
            eng.submit(tr.req).unwrap();
        }
        eng.run_to_completion().unwrap();
        let wall = now_secs() - start;
        let tput = eng.metrics.committed_tokens as f64 / wall;
        let b = *base.get_or_insert(tput);
        tab.row(vec![
            label.into(),
            format!("{tput:.1}"),
            format!("{:+.1}%", (tput / b - 1.0) * 100.0),
        ]);
        let _ = eng.take_finished();
    }
    println!("{}", tab.render());
}
