//! Streaming and cancellation over the wire: starts a server on a
//! loopback port, streams one deterministic request commit by commit,
//! cancels a long request mid-flight from a second connection, and prints
//! the per-reason finish counters.
//!
//!     make artifacts && cargo run --release --example streaming_cancel
//!
//! Shows the serving-surface half of LLM-42: only *committed* tokens are
//! streamed (speculative fast-path tokens can be rolled back by the
//! verifier, streamed text never is), and an aborted request returns its
//! committed prefix plus `finish_reason: "cancelled"` while its KV pages
//! go back to the pool.

use llm42::engine::EngineConfig;
use llm42::error::Result;
use llm42::server::{Client, Server, StreamEvent};
use llm42::tokenizer::Tokenizer;
use llm42::util::json::Json;

fn main() -> Result<()> {
    let artifacts =
        std::env::var("LLM42_ARTIFACTS").unwrap_or_else(|_| "artifacts".into());
    llm42::aot::ensure(&artifacts)?;
    let man = llm42::manifest::Manifest::load(&artifacts)?;
    println!("training tokenizer...");
    let tok = Tokenizer::default_trained(man.model.vocab)?;
    let server =
        Server::start(artifacts, EngineConfig::default(), tok, "127.0.0.1:0")?;
    let addr = server.addr.to_string();
    println!("serving on {addr}\n");

    // --- stream a deterministic request, delta by delta --------------------
    let mut c = Client::connect(&addr)?;
    let req = Json::parse(
        r#"{"text": "the quick brown fox", "max_new_tokens": 24,
            "deterministic": true, "temperature": 1.0, "seed": 7}"#,
    )?;
    println!("streaming a deterministic request:");
    for ev in c.stream(&req)? {
        match ev? {
            StreamEvent::Delta { id, tokens, text } => {
                println!("  #{id} +{} tokens: {text:?}", tokens.len());
            }
            StreamEvent::Done(v) => {
                println!(
                    "  done: finish_reason={} ttft={:.0}ms e2e={:.0}ms",
                    v.s("finish_reason")?,
                    v.f("ttft_ms")?,
                    v.f("e2e_ms")?
                );
            }
        }
    }

    // --- cancel a long request mid-stream from a second connection ---------
    let mut side = Client::connect(&addr)?;
    // deterministic: tokens surface in verify-window bursts, so the
    // cancel reliably lands while the request is still mid-flight
    let long = Json::parse(
        r#"{"text": "once upon a time", "max_new_tokens": 100,
            "deterministic": true, "temperature": 1.0, "seed": 11}"#,
    )?;
    println!("\nstreaming a long request, cancelling after the first delta:");
    let mut it = c.stream(&long)?;
    let first = it.next().expect("stream event")?;
    let id = match first {
        StreamEvent::Delta { id, ref text, .. } => {
            println!("  #{id} first delta: {text:?}");
            id
        }
        StreamEvent::Done(v) => {
            return Err(llm42::error::Error::Server(format!(
                "finished before the first delta: {}",
                v.dump()
            )))
        }
    };
    let ack =
        side.request(&Json::parse(&format!(r#"{{"cmd":"cancel","id":{id}}}"#))?)?;
    println!("  cancel ack: {}", ack.dump());
    for ev in it {
        if let StreamEvent::Done(v) = ev? {
            println!(
                "  final: finish_reason={} ({} tokens kept)",
                v.s("finish_reason")?,
                v.arr("tokens")?.len()
            );
        }
    }

    // --- lifecycle accounting ----------------------------------------------
    let stats = side.request(&Json::parse(r#"{"cmd":"stats"}"#)?)?;
    println!("\nfinish_reasons: {}", stats.req("finish_reasons")?.dump());
    println!(
        "kv available_pages: {}",
        stats.req("kv")?.u("available_pages")?
    );
    server.shutdown();
    Ok(())
}
