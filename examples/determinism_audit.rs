//! Determinism audit: the paper's headline guarantee, demonstrated.
//!
//!     cargo run --release --example determinism_audit -- \
//!         [--verify-policy stall|slack|margin-gate] \
//!         [--tp R --collective ring|tree|multimem]
//!
//! Runs one audited (deterministic) request under three adversarial
//! co-traffic schedules — solo, a small crowd, and a large bursty crowd —
//! and proves the committed output is bitwise identical every time, while
//! the *unverified* fast path of a control request drifts across the same
//! schedules. This is the regression-test / safety-audit use case the
//! paper motivates (O4): pin `is_deterministic=true` on audited traffic
//! only, and leave the rest at full speed.
//!
//! The comparison runs on committed-stream digests (`stream_digest` —
//! the FNV-1a chain the engine maintains per sequence at every obs
//! level), not on buffered token vectors: comparing one integer per run
//! is how a replica set or a CI job would audit determinism. Each
//! schedule also prints `engine_digest=0x...` — the engine-wide fold
//! over all retired requests — which CI diffs across thread counts —
//! and `audit_digest=0x...`, the audited stream alone, which CI
//! additionally diffs across verification triggers (the engine-wide fold
//! covers nondeterministic co-traffic, whose streams legitimately shift
//! when the trigger reschedules work; the audited stream must not).
//!
//! A final deterministic-only schedule prints `det_engine_digest=0x...`:
//! with every request deterministic, even the engine-wide fold must be
//! bitwise identical under `--verify-policy stall` vs `margin-gate` —
//! the certificate path may change how many verification forwards run,
//! never what commits.
//!
//! With `--tp R --collective C` the audit runs on a tensor-parallel
//! sharded artifact set instead: CI invokes it at R = 1, 2, 4 under the
//! tree collective and diffs the `engine_digest=` lines across rank
//! counts — the cross-R face of the same determinism contract.
//!
//! With `--replicas N` the deterministic-only workload is additionally
//! routed through an N-replica [`Router`] fleet. Global request ids are a
//! pure function of submission order, so the router's fleet digest —
//! `fold_stream(global_id, stream_digest)` over deterministic streams —
//! must be bitwise identical at any replica count: CI invokes this at
//! N = 1, 2, 4 and diffs the `fleet_digest=` lines, the cross-replica
//! face of the contract.

use llm42::obs::{digest_hex, digest_stream};
use llm42::prelude::*;
use llm42::util::cli::Args;
use llm42::util::rng::SplitMix64;

fn co_traffic(seed: u64, n: usize, vocab: usize) -> Vec<Request> {
    let mut rng = SplitMix64::new(seed);
    (0..n)
        .map(|_| Request {
            prompt: (0..8 + rng.below(24) as usize)
                .map(|_| 3 + rng.below(vocab as u64 - 3) as u32)
                .collect(),
            max_new_tokens: 8 + rng.below(56) as usize,
            deterministic: false,
            temperature: 1.0,
            seed: rng.next_u64(),
            ..Default::default()
        })
        .collect()
}

fn main() -> Result<()> {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let args = Args::parse(&argv);
    let verify_policy = VerifyPolicy::new(VerifyPolicyKind::parse(
        &args.str_or("verify-policy", "stall"),
    )?);
    let base =
        std::env::var("LLM42_ARTIFACTS").unwrap_or_else(|_| "artifacts".into());
    let tp = args.usize_or("tp", 0)?;
    let artifacts = if tp > 0 {
        // a sharded set per (R, collective) point, generated on demand —
        // same test preset, so streams are comparable across R
        let collective = args.str_or("collective", "tree");
        let dir = format!("{base}-tp{tp}-{collective}");
        llm42::aot::ensure_tp(&dir, tp, &collective)?;
        dir
    } else {
        llm42::aot::ensure(&base)?;
        base
    };
    let mut rt = Runtime::load(&artifacts)?;
    let vocab = rt.dims().vocab;
    println!("verify policy: {}", verify_policy.kind.name());
    if rt.tp_collective() != "none" {
        println!(
            "tensor parallel: {} ranks, {} collective",
            rt.tp_degree(),
            rt.tp_collective()
        );
    }

    let audited = Request {
        prompt: (100..140).collect(),
        max_new_tokens: 64,
        deterministic: true,
        temperature: 1.0,
        seed: 4242,
        ..Default::default()
    };
    let schedules: Vec<(&str, Vec<Request>)> = vec![
        ("solo", vec![]),
        ("crowd of 4", co_traffic(1, 4, vocab)),
        ("crowd of 11", co_traffic(2, 11, vocab)),
    ];

    let mut audited_digests = Vec::new();
    let mut control_digests = Vec::new();
    for (name, co) in &schedules {
        let mut eng = Engine::new(
            &mut rt,
            EngineConfig {
                mode: Mode::Llm42,
                verify_policy,
                ..Default::default()
            },
        )?;
        eng.warmup()?;
        let audit_id = eng.submit(audited.clone())?;
        // control: same prompt, same seed, but unverified
        let mut control = audited.clone();
        control.deterministic = false;
        let control_id = eng.submit(control)?;
        for r in co {
            eng.submit(r.clone())?;
        }
        eng.run_to_completion()?;
        let outs = eng.take_finished();
        let audit = outs.iter().find(|o| o.id == audit_id).unwrap();
        let ctrl = outs.iter().find(|o| o.id == control_id).unwrap();
        // the running chain must equal a from-scratch digest of the
        // committed tokens — the provenance layer's core invariant
        assert_eq!(
            audit.stream_digest,
            digest_stream(&audit.tokens),
            "stream digest chain diverged from the committed stream"
        );
        println!(
            "schedule {name:>12}: audited {} tokens, digest {} ({} rollbacks, \
             {} recomputed) | control {} tokens",
            audit.tokens.len(),
            digest_hex(audit.stream_digest),
            audit.metrics.rollbacks,
            audit.metrics.recomputed_tokens,
            ctrl.tokens.len(),
        );
        // engine-wide fold over every retired request in this schedule;
        // CI greps these lines and diffs them across thread counts
        println!("engine_digest={}", digest_hex(eng.obs.engine_digest()));
        // the audited stream alone: trigger-invariant even with nondet
        // co-traffic, so CI also diffs these across --verify-policy
        println!("audit_digest={}", digest_hex(audit.stream_digest));
        audited_digests.push(audit.stream_digest);
        control_digests.push(ctrl.stream_digest);
    }

    // deterministic-only schedule: every retired stream is deterministic,
    // so the engine-wide fold itself must be verification-trigger- and
    // thread-count-invariant. CI diffs this line across both.
    {
        let mut eng = Engine::new(
            &mut rt,
            EngineConfig {
                mode: Mode::Llm42,
                verify_policy,
                ..Default::default()
            },
        )?;
        eng.warmup()?;
        eng.submit(audited.clone())?;
        for i in 0..3u32 {
            eng.submit(Request {
                prompt: (200 + 20 * i..216 + 20 * i).collect(),
                max_new_tokens: 24 + 4 * i as usize,
                deterministic: true,
                temperature: if i == 0 { 0.0 } else { 1.0 },
                seed: 9000 + i as u64,
                ..Default::default()
            })?;
        }
        eng.run_to_completion()?;
        eng.take_finished();
        println!(
            "schedule     det-only: {} certified, {} verified, {} repair \
             tokens, {} verify passes",
            eng.metrics.certified_tokens,
            eng.metrics.verified_tokens,
            eng.metrics.gate_repair_tokens,
            eng.metrics.verify_passes,
        );
        println!("det_engine_digest={}", digest_hex(eng.obs.engine_digest()));
    }

    // multi-replica fleet audit: the same deterministic workload through
    // N engine replicas. Per-replica engine digests fold engine-local ids
    // and legitimately differ across N; the fleet digest folds global ids
    // and must not. CI diffs the fleet_digest= lines across --replicas.
    let replicas = args.usize_or("replicas", 0)?;
    if replicas > 0 {
        let tok = std::sync::Arc::new(
            llm42::tokenizer::Tokenizer::default_trained(vocab)?,
        );
        let cfg = EngineConfig {
            mode: Mode::Llm42,
            verify_policy,
            replicas,
            ..Default::default()
        };
        let router = Router::new(&artifacts, &cfg, tok);
        let mut reqs = vec![audited.clone()];
        for i in 0..3u32 {
            reqs.push(Request {
                prompt: (200 + 20 * i..216 + 20 * i).collect(),
                max_new_tokens: 24 + 4 * i as usize,
                deterministic: true,
                temperature: if i == 0 { 0.0 } else { 1.0 },
                seed: 9000 + i as u64,
                ..Default::default()
            });
        }
        let mut rxs = Vec::with_capacity(reqs.len());
        for r in reqs {
            let (tx, rx) = std::sync::mpsc::channel();
            router.submit(r, tx);
            rxs.push(rx);
        }
        for rx in &rxs {
            loop {
                match rx.recv().expect("replica reply channel closed") {
                    ConnEvent::Done(line) => {
                        assert!(
                            !line.contains("\"error\""),
                            "fleet audit request failed: {line}"
                        );
                        break;
                    }
                    ConnEvent::Accepted(_) | ConnEvent::Line(_) => {}
                }
            }
        }
        println!("schedule  fleet-of-{replicas}:");
        for (i, (live, snap)) in router.snapshots().into_iter().enumerate() {
            if let Some(s) = snap {
                println!(
                    "  replica[{i}] live={live} streams={} engine_digest={}",
                    s.digest_seqs,
                    digest_hex(s.engine_digest)
                );
            }
        }
        let c = router.counters();
        println!("fleet_digest={}", digest_hex(c.fleet_digest));
        println!("fleet_sequences={}", c.fleet_seqs);
        router.join();
    }

    println!();
    let all_equal = audited_digests.windows(2).all(|w| w[0] == w[1]);
    println!(
        "audited digest identical across schedules: {}",
        if all_equal { "YES ✓" } else { "NO ✗ (bug!)" }
    );
    let ctrl_equal = control_digests.windows(2).all(|w| w[0] == w[1]);
    println!(
        "unverified control identical across schedules:      {}",
        if ctrl_equal {
            "yes (no flip boundary crossed this time — logits still drifted; \
             see `llm42 experiments fig6` for flip statistics)"
        } else {
            "NO — fast path drifted, exactly the paper's Fig. 6 behaviour"
        }
    );
    assert!(all_equal, "determinism guarantee violated");
    Ok(())
}
