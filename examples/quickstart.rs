//! Quickstart: load the engine, serve a handful of mixed requests
//! in-process, and print decoded text.
//!
//!     make artifacts && cargo run --release --example quickstart
//!
//! Shows the paper's per-request `is_deterministic` flag (O4): two of the
//! requests ask for determinism and go through decode-verify-rollback;
//! the rest ride the fast path untouched.

use llm42::prelude::*;
use llm42::tokenizer::Tokenizer;

fn main() -> Result<()> {
    let artifacts =
        std::env::var("LLM42_ARTIFACTS").unwrap_or_else(|_| "artifacts".into());
    llm42::aot::ensure(&artifacts)?;
    println!("loading runtime from {artifacts}/ ...");
    let mut rt = Runtime::load(&artifacts)?;
    println!(
        "model '{}': {:.1}M params, vocab {}, {} KV slots",
        rt.dims().name,
        rt.dims().n_params() as f64 / 1e6,
        rt.dims().vocab,
        rt.dims().user_slots()
    );

    println!("training byte-BPE tokenizer (embedded corpus)...");
    let tok = Tokenizer::default_trained(rt.dims().vocab)?;

    let mut eng = Engine::new(&mut rt, EngineConfig::default())?;
    eng.warmup()?;

    let prompts = [
        ("the quick brown fox", true),
        ("deterministic inference with dynamic batching", true),
        ("once upon a time", false),
        ("large language model serving", false),
        ("floating point addition is not associative", false),
    ];
    for (text, det) in prompts {
        let req = Request {
            prompt: tok.encode(text),
            max_new_tokens: 24,
            deterministic: det,
            temperature: 1.0,
            seed: 42,
            ..Default::default()
        };
        let id = eng.submit(req)?;
        println!("submitted #{id} (deterministic={det}): {text:?}");
    }

    eng.run_to_completion()?;

    println!("\n--- outputs ---");
    let mut outs = eng.take_finished();
    outs.sort_by_key(|o| o.id);
    for o in &outs {
        println!(
            "#{} [{}] {:>3} tokens, ttft {:.0} ms, rollbacks {}: {:?}",
            o.id,
            if o.deterministic { "det" } else { "fst" },
            o.tokens.len(),
            o.metrics.ttft().unwrap_or(f64::NAN) * 1e3,
            o.metrics.rollbacks,
            tok.decode(&o.tokens)
        );
    }
    let m = &eng.metrics;
    println!(
        "\nengine: {} decode steps, {} verify passes, {} committed tokens, \
         {} recomputed ({:.2}%)",
        m.decode_steps,
        m.verify_passes,
        m.committed_tokens,
        m.recomputed_tokens,
        m.recompute_ratio() * 100.0
    );
    Ok(())
}
