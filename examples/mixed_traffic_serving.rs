//! End-to-end serving driver (the EXPERIMENTS.md validation run).
//!
//!     cargo run --release --example mixed_traffic_serving -- \
//!         [--requests 48] [--qps 4] [--det-ratio 0.1] [--mode llm42] \
//!         [--policy prefill-first|deadline|fair-share] [--det-priority 4] \
//!         [--det-deadline-ms 400] [--workload sharegpt|arxiv|multiturn] \
//!         [--prefix-cache true|false] [--max-step-tokens N] \
//!         [--verify-policy stall|slack|margin-gate] \
//!         [--replicas N] [--router-queue N] [--router-affinity true|false]
//!
//! Serves an online ShareGPT-shaped workload (Poisson arrivals) with a
//! mixed deterministic ratio through the full three-layer stack — rust
//! scheduler -> AOT HLO graphs -> pallas/jnp kernels — and reports
//! throughput, latency, TTFT, DVR overhead, and the scheduling-policy
//! counters (preemptions, re-prefilled tokens, queue pressure, per-class
//! latency). Deterministic requests are tagged with `--det-priority` /
//! `--det-deadline-ms` so the deadline / fair-share policies have classes
//! to arbitrate. Compares against the non-deterministic ceiling and the
//! batch-invariant baseline when `--compare` is passed.
//!
//! With `--replicas N` (N > 1) the same trace is served through the
//! multi-replica [`Router`] instead of a single engine: prefix-affinity
//! placement, per-priority backpressure (shed requests finish
//! `overloaded`), per-replica engine digests, and the replica-count-
//! invariant fleet digest.

use llm42::engine::{EngineConfig, Mode, PolicyKind, StepKind, VerifyPolicy, VerifyPolicyKind};
use llm42::obs::digest_hex;
use llm42::prelude::*;
use llm42::trace::{LengthProfile, TraceSpec};
use llm42::util::cli::Args;
use llm42::util::now_secs;
use llm42::util::stats::Recorder;

fn main() -> Result<()> {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let args = Args::parse(&argv);
    let artifacts = args.str_or("artifacts", "artifacts");
    llm42::aot::ensure(&artifacts)?;
    let mut rt = Runtime::load(&artifacts)?;
    let dims = rt.dims().clone();

    let profile = match args.str_or("workload", "sharegpt").as_str() {
        "sharegpt" => LengthProfile::sharegpt(),
        "arxiv" => LengthProfile::arxiv(),
        "multiturn" => LengthProfile::multiturn(),
        other => {
            eprintln!("unknown --workload '{other}' (sharegpt | arxiv | multiturn)");
            std::process::exit(2);
        }
    };
    let spec = TraceSpec {
        profile,
        n_requests: args.usize_or("requests", 48)?,
        det_ratio: args.f64_or("det-ratio", 0.1)?,
        qps: Some(args.f64_or("qps", 4.0)?),
        seed: args.u64_or("seed", 42)?,
        temperature: 1.0,
        vocab: dims.vocab,
        max_seq: dims.max_seq,
        window: args.usize_or("window", 32)?,
    };

    let modes: Vec<Mode> = if args.has("compare") {
        vec![Mode::NonDeterministic, Mode::BatchInvariant, Mode::Llm42]
    } else {
        vec![Mode::parse(&args.str_or("mode", "llm42"))?]
    };
    let policy = PolicyKind::parse(&args.str_or("policy", "prefill-first"))?;
    let verify_policy = VerifyPolicy::new(VerifyPolicyKind::parse(
        &args.str_or("verify-policy", "stall"),
    )?);
    let det_priority = args.usize_or("det-priority", 4)?.min(255) as u8;
    let det_deadline_ms = args.f64_or("det-deadline-ms", 400.0)?;

    let replicas = args.usize_or("replicas", 1)?;
    for mode in modes {
        let cfg = EngineConfig {
            mode,
            verify_group: args.usize_or("group", 8)?,
            verify_window: args.usize_or("window", 32)?,
            policy,
            verify_policy,
            prefix_cache: args.bool_or("prefix-cache", false)?,
            // 0 = seed-exclusive steps; N fuses prefill chunks + the
            // decode batch into one forward per step (verify overlapped)
            max_step_tokens: args.usize_or("max-step-tokens", 0)?,
            replicas,
            router_queue: args.usize_or("router-queue", 32)?,
            router_affinity: args.bool_or("router-affinity", true)?,
            ..Default::default()
        };
        if replicas > 1 {
            serve_fleet(&artifacts, cfg, &spec, det_priority, det_deadline_ms, dims.vocab)?;
        } else {
            serve(&mut rt, cfg, &spec, det_priority, det_deadline_ms)?;
        }
    }
    Ok(())
}

/// Serve the trace through the multi-replica router: same Poisson
/// arrivals, routed by prefix affinity with per-priority backpressure.
fn serve_fleet(
    artifacts: &str,
    cfg: EngineConfig,
    spec: &TraceSpec,
    det_priority: u8,
    det_deadline_ms: f64,
    vocab: usize,
) -> Result<()> {
    println!(
        "== mode {:?}, policy {}, {} replicas (queue {}, affinity {}) ==",
        cfg.mode,
        cfg.policy.name(),
        cfg.replicas,
        cfg.router_queue,
        if cfg.router_affinity { "on" } else { "off" }
    );
    let mut trace = spec.generate();
    for tr in trace.iter_mut() {
        if tr.req.deterministic {
            tr.req.priority = det_priority;
            tr.req.deadline_ms = Some(det_deadline_ms);
        }
    }
    let tok = std::sync::Arc::new(
        llm42::tokenizer::Tokenizer::default_trained(vocab)?,
    );
    let router = Router::new(artifacts, &cfg, tok);

    let start = now_secs();
    let mut rxs = Vec::with_capacity(trace.len());
    for tr in &trace {
        let wait = tr.arrival_offset - (now_secs() - start);
        if wait > 0.0 {
            std::thread::sleep(std::time::Duration::from_secs_f64(wait));
        }
        let (tx, rx) = std::sync::mpsc::channel();
        router.submit(tr.req.clone(), tx);
        rxs.push(rx);
    }
    let (mut done, mut overloaded, mut errors) = (0u64, 0u64, 0u64);
    for rx in &rxs {
        loop {
            match rx.recv().expect("router reply channel closed") {
                ConnEvent::Done(line) => {
                    let v = llm42::util::json::Json::parse(&line)?;
                    if v.get("error").is_some() {
                        errors += 1;
                    } else if v.s("finish_reason")? == "overloaded" {
                        overloaded += 1;
                    }
                    done += 1;
                    break;
                }
                ConnEvent::Accepted(_) | ConnEvent::Line(_) => {}
            }
        }
    }
    let wall = now_secs() - start;

    let c = router.counters();
    println!(
        "  {done} requests in {wall:.1}s ({overloaded} shed 'overloaded', \
         {errors} errors)"
    );
    let hit_rate = if c.routed > 0 {
        c.affinity_hits as f64 / c.routed as f64 * 100.0
    } else {
        0.0
    };
    println!(
        "  router: routed {} | affinity hits {} ({hit_rate:.0}%) | shed {}",
        c.routed, c.affinity_hits, c.shed
    );
    let mut committed = 0u64;
    for (i, (live, snap)) in router.snapshots().into_iter().enumerate() {
        if let Some(s) = snap {
            committed += s.metrics.committed_tokens;
            println!(
                "    replica[{i}] live={live}: {} steps, {} committed tokens, \
                 engine_digest={}",
                s.metrics.steps,
                s.metrics.committed_tokens,
                digest_hex(s.engine_digest)
            );
        }
    }
    println!(
        "  throughput: {:.1} output tok/s across the fleet",
        committed as f64 / wall
    );
    println!(
        "  fleet_digest={} ({} deterministic streams)\n",
        digest_hex(c.fleet_digest),
        c.fleet_seqs
    );
    router.join();
    Ok(())
}

fn serve(
    rt: &mut Runtime,
    cfg: EngineConfig,
    spec: &TraceSpec,
    det_priority: u8,
    det_deadline_ms: f64,
) -> Result<()> {
    println!(
        "== mode {:?}, policy {}, verify {}, workload {}, det ratio {:.0}%, \
         prefix cache {} ==",
        cfg.mode,
        cfg.policy.name(),
        cfg.verify_policy.kind.name(),
        spec.profile.name(),
        spec.det_ratio * 100.0,
        if cfg.prefix_cache { "on" } else { "off" }
    );
    let mut trace = spec.generate();
    // deterministic traffic is the latency-sensitive class
    for tr in trace.iter_mut() {
        if tr.req.deterministic {
            tr.req.priority = det_priority;
            tr.req.deadline_ms = Some(det_deadline_ms);
        }
    }
    let mut eng = Engine::new(rt, cfg)?;
    eng.warmup()?;

    let start = now_secs();
    let mut next = 0usize;
    loop {
        while next < trace.len() && now_secs() - start >= trace[next].arrival_offset {
            eng.submit(trace[next].req.clone())?;
            next += 1;
        }
        if next >= trace.len() && eng.idle() {
            break;
        }
        if eng.step()? == StepKind::Idle {
            std::thread::sleep(std::time::Duration::from_millis(1));
        }
    }
    let wall = now_secs() - start;

    let outs = eng.take_finished();
    let mut e2e = Recorder::new();
    let mut ttft = Recorder::new();
    let (mut det_n, mut det_rollbacks, mut det_recomputed) = (0u64, 0u64, 0u64);
    for o in &outs {
        e2e.record(o.metrics.e2e());
        // aborted-before-first-token requests have no TTFT sample
        if let Some(t) = o.metrics.ttft() {
            ttft.record(t * 1e3);
        }
        if o.deterministic {
            det_n += 1;
            det_rollbacks += o.metrics.rollbacks;
            det_recomputed += o.metrics.recomputed_tokens;
        }
    }
    let m = &eng.metrics;
    println!(
        "  {} requests ({} deterministic) in {:.1}s",
        outs.len(),
        det_n,
        wall
    );
    println!(
        "  throughput: {:.1} output tok/s | {:.1} total tok/s (incl. prefill)",
        m.committed_tokens as f64 / wall,
        (m.committed_tokens + m.prefill_tokens) as f64 / wall
    );
    println!(
        "  latency e2e: p50 {:.2}s p90 {:.2}s p99 {:.2}s | ttft: p50 {:.0}ms p90 {:.0}ms",
        e2e.percentile(50.0),
        e2e.percentile(90.0),
        e2e.percentile(99.0),
        ttft.percentile(50.0),
        ttft.percentile(90.0)
    );
    println!(
        "  DVR: {} verify passes, {} rollbacks, {} recomputed tokens ({:.2}% of decoded)",
        m.verify_passes,
        det_rollbacks,
        det_recomputed,
        m.recompute_ratio() * 100.0
    );
    println!(
        "  margin gate: {} certified, {} verified, {} repair tokens",
        m.certified_tokens, m.verified_tokens, m.gate_repair_tokens
    );
    println!(
        "  scheduling: {} preemptions, {} re-prefilled tokens, queue depth hwm {}",
        m.preemptions, m.reprefilled_tokens, m.queue_depth_hwm
    );
    println!(
        "  step composer: {} forwards ({:.2} per committed token), {} fused \
         steps carrying {} tokens ({:.0}% budget occupancy)",
        m.forward_passes,
        m.forwards_per_committed_token(),
        m.fused_steps,
        m.fused_fwd_tokens,
        m.fused_occupancy() * 100.0
    );
    let kv = eng.kv_stats();
    println!(
        "  KV: {} pages x {} positions | free {} cached {} held {} | evicted {}",
        kv.user_pages, kv.block_size, kv.free_pages, kv.cached_pages, kv.held_pages,
        kv.evicted_pages
    );
    println!(
        "  prefix cache: {} hits, {} tokens served from cache ({:.0}% hit rate), \
         {} re-prefill tokens saved, {} COW copies",
        m.cache_hits,
        m.cache_hit_tokens,
        m.cache_hit_rate() * 100.0,
        m.reprefill_saved_tokens,
        m.cow_copies
    );
    for (class, c) in &m.class_e2e {
        println!(
            "    class {class}: {} finished, e2e mean {:.2}s max {:.2}s",
            c.finished,
            c.mean_e2e_secs(),
            c.max_e2e_secs
        );
    }
    println!(
        "  phase wall: decode {:.1}s, prefill {:.1}s, verify {:.1}s\n",
        m.decode_secs, m.prefill_secs, m.verify_secs
    );
    Ok(())
}
