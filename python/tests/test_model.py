"""L2 model correctness and the determinism-bearing structural properties.

Uses the `test` preset (2 layers, d=64) so each forward traces in well
under a second.
"""

import functools

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from compile.config import PRESETS, Strategy
from compile.model import (
    extract_logits,
    forward,
    forward_ref,
    init_weights,
    weight_shapes,
)

CFG = PRESETS["test"]
WEIGHTS = [w for _, w in init_weights(CFG)]
RNG = np.random.default_rng(7)


def run(g, t, strategy, tokens, slots, start, state=None):
    state = (
        jnp.zeros((CFG.state_floats,), jnp.float32) if state is None else state
    )
    fn = jax.jit(functools.partial(forward, CFG, g, t, strategy))
    return fn(
        state,
        jnp.asarray(tokens, jnp.int32),
        jnp.asarray(slots, jnp.int32),
        jnp.asarray(start, jnp.int32),
        *WEIGHTS,
    )


def logits_of(state, n):
    lo = CFG.logits_offset
    return np.asarray(state[lo : lo + n * CFG.vocab]).reshape(n, CFG.vocab)


def rand_tokens(n):
    return RNG.integers(1, CFG.vocab, n)


# ----------------------------------------------------------- correctness
@pytest.mark.parametrize("g,t", [(1, 1), (2, 1), (1, 8), (2, 4)])
def test_invariant_forward_matches_oracle(g, t):
    tokens = rand_tokens(g * t)
    slots = list(range(g))
    start = [0] * g
    got = run(g, t, Strategy.invariant(), tokens, slots, start)
    want = forward_ref(
        CFG, g, t,
        jnp.zeros((CFG.state_floats,), jnp.float32),
        jnp.asarray(tokens, jnp.int32),
        jnp.asarray(slots, jnp.int32),
        jnp.asarray(start, jnp.int32),
        WEIGHTS,
    )
    np.testing.assert_allclose(
        logits_of(got, g * t), logits_of(want, g * t), atol=2e-3, rtol=1e-3
    )


@pytest.mark.parametrize("bucket", [1, 2, 4])
def test_fast_forward_close_to_oracle(bucket):
    tokens = rand_tokens(bucket)
    got = run(bucket, 1, Strategy.fast(bucket), tokens, range(bucket), [0] * bucket)
    want = forward_ref(
        CFG, bucket, 1,
        jnp.zeros((CFG.state_floats,), jnp.float32),
        jnp.asarray(tokens, jnp.int32),
        jnp.asarray(range(bucket), jnp.int32),
        jnp.zeros((bucket,), jnp.int32),
        WEIGHTS,
    )
    # bf16 partials through 2 layers: loose but bounded
    np.testing.assert_allclose(
        logits_of(got, bucket), logits_of(want, bucket), atol=1.5, rtol=0.2
    )


def test_kv_cache_matches_multi_token_pass():
    """Decoding token-by-token == one multi-token window (same strategy)."""
    toks = rand_tokens(4)
    inv = Strategy.invariant()
    # one 4-token window
    full = run(1, 4, inv, toks, [0], [0])
    # token-by-token, threading state
    state = jnp.zeros((CFG.state_floats,), jnp.float32)
    for i in range(4):
        state = run(1, 1, inv, toks[i : i + 1], [0], [i], state)
    # last token's logits must agree (KV path correct); tolerance loose
    # because the reduction *shapes* differ between the two schedules.
    np.testing.assert_allclose(
        logits_of(full, 4)[3], logits_of(state, 1)[0], atol=2e-2, rtol=1e-2
    )


def test_sequential_same_shape_is_bitwise_reproducible():
    """O2 at model level: same executable shape, same inputs -> same bits."""
    toks = rand_tokens(2)
    a = run(2, 1, Strategy.fast(2), toks, [0, 1], [0, 0])
    b = run(2, 1, Strategy.fast(2), toks, [0, 1], [0, 0])
    np.testing.assert_array_equal(logits_of(a, 2), logits_of(b, 2))


# ------------------------------------------------ determinism mechanisms
def test_bucket_divergence():
    """Same token, different bucket strategies -> different bits (O1 cause)."""
    toks = rand_tokens(4)
    a = run(1, 1, Strategy.fast(1), toks[:1], [0], [0])
    b = run(4, 1, Strategy.fast(4), toks, [0, 1, 2, 3], [0, 0, 0, 0])
    la, lb = logits_of(a, 1)[0], logits_of(b, 4)[0]
    assert not np.array_equal(la, lb)
    # but drift is small relative to logit scale
    assert np.abs(la - lb).max() < 0.25 * np.abs(la).max()


def test_lane_permutation_invariance():
    """O2: a request's verify logits don't depend on its lane index."""
    t = 4
    toks_a, toks_b = rand_tokens(t), rand_tokens(t)
    inv = Strategy.invariant()
    ab = run(2, t, inv, np.concatenate([toks_a, toks_b]), [0, 2], [0, 0])
    ba = run(2, t, inv, np.concatenate([toks_b, toks_a]), [2, 0], [0, 0])
    la_first = logits_of(ab, 2 * t)[:t]
    la_second = logits_of(ba, 2 * t)[t:]
    np.testing.assert_array_equal(la_first, la_second)


def test_pad_lane_does_not_affect_real_lane():
    """Grouped-verification padding must be inert for real lanes."""
    t = 4
    toks = rand_tokens(t)
    trash = CFG.slots - 1
    inv = Strategy.invariant()
    alone = run(2, t, inv, np.concatenate([toks, [0] * t]), [0, trash], [0, 0])
    other = run(
        2, t, inv, np.concatenate([toks, rand_tokens(t)]), [0, trash], [0, 0]
    )
    np.testing.assert_array_equal(
        logits_of(alone, 2 * t)[:t], logits_of(other, 2 * t)[:t]
    )


def test_verifier_overwrites_decode_kv():
    """Replaying a window overwrites fast-path KV with invariant KV."""
    toks = rand_tokens(3)
    inv = Strategy.invariant()
    # fast pass writes its KV
    st_fast = run(1, 1, Strategy.fast(1), toks[:1], [0], [0])
    # verify window replays the same token from scratch on that state
    st_ver = run(1, 1, inv, toks[:1], [0], [0], st_fast)
    # reference: invariant from clean state
    st_clean = run(1, 1, inv, toks[:1], [0], [0])
    koff = CFG.kv_offset(0, 0, 0, 0)
    a = np.asarray(st_ver[koff : koff + CFG.kv_dim])
    b = np.asarray(st_clean[koff : koff + CFG.kv_dim])
    np.testing.assert_array_equal(a, b)


# ----------------------------------------------------------------- misc
def test_extract_logits_slices_rows():
    toks = rand_tokens(2)
    st = run(2, 1, Strategy.invariant(), toks, [0, 1], [0, 0])
    got = np.asarray(jax.jit(functools.partial(extract_logits, CFG, 2))(st))
    np.testing.assert_array_equal(got, logits_of(st, 2))


def test_weight_shapes_cover_param_count():
    total = sum(int(np.prod(s)) for _, s in weight_shapes(CFG))
    assert total == CFG.n_params()


def test_state_layout_constants():
    assert CFG.logits_offset == CFG.pool_floats
    assert CFG.state_floats == CFG.pool_floats + CFG.logits_floats
    assert CFG.kv_offset(0, 0, 0, 0) == 0
    assert CFG.kv_offset(1, 0, 0, 0) == CFG.pool_floats // 2
    # consecutive positions are contiguous kv_dim blocks
    assert CFG.kv_offset(0, 0, 0, 1) - CFG.kv_offset(0, 0, 0, 0) == CFG.kv_dim


def test_long_context_window_positions():
    """Windows starting deep in the sequence attend across the prefix."""
    inv = Strategy.invariant()
    state = jnp.zeros((CFG.state_floats,), jnp.float32)
    # prefill 8 tokens, then a window at position 8
    state = run(1, 8, inv, rand_tokens(8), [0], [0], state)
    out = run(1, 4, inv, rand_tokens(4), [0], [8], state)
    lg = logits_of(out, 4)
    assert np.isfinite(lg).all()
    assert lg.std() > 0.1  # prefix actually influenced the distribution
