"""L1 kernel correctness: pallas kernels vs pure-jnp oracles.

Covers the allclose contract, the exact structural properties the system
relies on (split-count divergence, row independence), and randomized
shape/value sweeps (a seeded mini-hypothesis: the environment has no
`hypothesis` package, so we sweep an explicit seeded grid instead).
"""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from compile.kernels.ref import matmul_ref, rmsnorm_ref
from compile.kernels.rmsnorm import rmsnorm
from compile.kernels.splitk_matmul import (
    combine_tree,
    matmul,
    seqchunk_matmul,
    splitk_matmul,
)

RNG = np.random.default_rng(42)


def rand(shape, scale=1.0):
    return jnp.asarray(RNG.normal(0, scale, shape), jnp.float32)


# ---------------------------------------------------------------- combine
def test_combine_tree_exact_sum_small_ints():
    # integers below 2^20 are exact in f32: tree must equal plain sum
    parts = jnp.asarray(RNG.integers(-100, 100, (8, 4, 4)), jnp.float32)
    got = combine_tree(parts)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(parts.sum(0)))


@pytest.mark.parametrize("n", [1, 2, 4, 8, 16])
def test_combine_tree_close_to_sum(n):
    parts = rand((n, 8, 8))
    got = combine_tree(parts)
    np.testing.assert_allclose(
        np.asarray(got), np.asarray(parts.sum(0)), rtol=1e-5, atol=1e-5
    )


def test_combine_tree_rejects_non_power_of_two():
    with pytest.raises(AssertionError):
        combine_tree(rand((3, 2, 2)))


# ---------------------------------------------------------------- split-K
@pytest.mark.parametrize("m", [1, 3, 16, 64])
@pytest.mark.parametrize("nsplits", [1, 2, 4, 8])
def test_splitk_matmul_close_to_ref(m, nsplits):
    x, w = rand((m, 64)), rand((64, 48))
    got = splitk_matmul(x, w, nsplits=nsplits)
    want = matmul_ref(x, w)
    # bf16 partials: tolerance scales with the partial magnitude
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=0.35, rtol=0.05)


@pytest.mark.parametrize("nsplits", [1, 2, 4, 8])
def test_splitk_f32_partials_tight(nsplits):
    x, w = rand((8, 64)), rand((64, 32))
    got = splitk_matmul(x, w, nsplits=nsplits, partial_dtype="float32")
    np.testing.assert_allclose(
        np.asarray(got), np.asarray(matmul_ref(x, w)), atol=2e-5, rtol=1e-5
    )


def test_splitk_deterministic_per_schedule():
    x, w = rand((4, 64)), rand((64, 32))
    a = np.asarray(splitk_matmul(x, w, nsplits=4))
    b = np.asarray(splitk_matmul(x, w, nsplits=4))
    np.testing.assert_array_equal(a, b)


def test_splitk_divergence_across_split_counts():
    """The paper's Fig. 3 effect: different split counts, different bits."""
    x, w = rand((4, 256), 2.0), rand((256, 64), 2.0)
    a = np.asarray(splitk_matmul(x, w, nsplits=2))
    b = np.asarray(splitk_matmul(x, w, nsplits=8))
    assert not np.array_equal(a, b)


def test_splitk_row_independence():
    """Position invariance (O2): a row's result doesn't depend on others."""
    x, w = rand((8, 64)), rand((64, 32))
    full = np.asarray(splitk_matmul(x, w, nsplits=4))
    x2 = x.at[3:].set(rand((5, 64)))  # perturb OTHER rows
    part = np.asarray(splitk_matmul(x2, w, nsplits=4))
    np.testing.assert_array_equal(full[:3], part[:3])


def test_splitk_rejects_bad_split():
    with pytest.raises(AssertionError):
        splitk_matmul(rand((2, 30)), rand((30, 4)), nsplits=4)


# ------------------------------------------------------------- invariant
@pytest.mark.parametrize("m", [1, 5, 32])
@pytest.mark.parametrize("chunks", [1, 4, 8])
def test_seqchunk_matmul_close_to_ref(m, chunks):
    x, w = rand((m, 64)), rand((64, 48))
    got = seqchunk_matmul(x, w, chunks=chunks)
    np.testing.assert_allclose(
        np.asarray(got), np.asarray(matmul_ref(x, w)), atol=5e-5, rtol=1e-4
    )


def test_seqchunk_row_independence_across_batch_sizes():
    """Batch invariance: row 0 identical whether batched with 1 or 16 rows."""
    w = rand((64, 48))
    x16 = rand((16, 64))
    a = np.asarray(seqchunk_matmul(x16[:1], w, chunks=8))
    b = np.asarray(seqchunk_matmul(x16, w, chunks=8))
    np.testing.assert_array_equal(a[0], b[0])


def test_matmul_dispatch():
    x, w = rand((2, 32)), rand((32, 16))
    np.testing.assert_allclose(
        np.asarray(matmul(x, w, kind="fast", nsplits=2)),
        np.asarray(matmul_ref(x, w)),
        atol=0.3,
        rtol=0.05,
    )
    with pytest.raises(ValueError):
        matmul(x, w, kind="bogus")


# --------------------------------------------------------------- rmsnorm
@pytest.mark.parametrize("m", [1, 4, 33])
@pytest.mark.parametrize("nsplit", [1, 2, 4])
def test_rmsnorm_close_to_ref(m, nsplit):
    x, w = rand((m, 64)), rand((64,)) + 1.0
    got = rmsnorm(x, w, nsplit=nsplit)
    np.testing.assert_allclose(
        np.asarray(got), np.asarray(rmsnorm_ref(x, w)), atol=1e-4, rtol=1e-4
    )


def test_rmsnorm_split_schedules_agree_within_tolerance():
    # Different reduction trees may drift in the low-order bits (on XLA-CPU
    # the SIMD reduction often coincides for both schedules; the GEMM
    # kernel is the guaranteed drift source). The contract we rely on is
    # only that both schedules are *valid* RMSNorms.
    x, w = rand((4, 256), 3.0), jnp.ones((256,), jnp.float32)
    a = np.asarray(rmsnorm(x, w, nsplit=1))
    b = np.asarray(rmsnorm(x, w, nsplit=4))
    np.testing.assert_allclose(a, b, atol=1e-4)


def test_rmsnorm_row_independence():
    x, w = rand((6, 64)), rand((64,))
    full = np.asarray(rmsnorm(x, w, nsplit=2))
    x2 = x.at[2:].set(rand((4, 64)))
    part = np.asarray(rmsnorm(x2, w, nsplit=2))
    np.testing.assert_array_equal(full[:2], part[:2])


# ------------------------------------------ randomized shape/value sweep
@pytest.mark.parametrize("case", range(12))
def test_splitk_random_sweep(case):
    """Seeded sweep over shapes/magnitudes (hypothesis-style, no dep)."""
    rng = np.random.default_rng(1000 + case)
    m = int(rng.integers(1, 64))
    k = int(rng.choice([32, 64, 128, 256]))
    n = int(rng.integers(1, 96))
    nsplits = int(rng.choice([1, 2, 4, 8]))
    scale = float(rng.choice([0.1, 1.0, 10.0]))
    x = jnp.asarray(rng.normal(0, scale, (m, k)), jnp.float32)
    w = jnp.asarray(rng.normal(0, scale, (k, n)), jnp.float32)
    got = np.asarray(splitk_matmul(x, w, nsplits=nsplits))
    want = np.asarray(matmul_ref(x, w))
    tol = 0.02 * scale * scale * np.sqrt(k) + 1e-5
    np.testing.assert_allclose(got, want, atol=tol, rtol=0.05)
    assert got.shape == (m, n)
    assert np.isfinite(got).all()


# ---------------------------------------- pallas <-> XLA-native twins
@pytest.mark.parametrize("nsplits", [1, 2, 4, 8])
def test_jnp_splitk_bitwise_equals_pallas(nsplits):
    """The serving graphs call jnp_splitk_matmul; it must be bit-for-bit
    the pallas kernel (same tiles, same bf16 partial rounding, same tree).
    """
    from compile.kernels.splitk_matmul import jnp_splitk_matmul

    rng = np.random.default_rng(5 + nsplits)
    x = jnp.asarray(rng.normal(0, 2, (8, 64)), jnp.float32)
    w = jnp.asarray(rng.normal(0, 2, (64, 48)), jnp.float32)
    a = np.asarray(splitk_matmul(x, w, nsplits=nsplits))
    b = np.asarray(jnp_splitk_matmul(x, w, nsplits=nsplits))
    np.testing.assert_array_equal(a, b)


@pytest.mark.parametrize("nsplit", [1, 2, 4])
def test_jnp_rmsnorm_bitwise_equals_pallas(nsplit):
    from compile.kernels.rmsnorm import jnp_rmsnorm

    rng = np.random.default_rng(9 + nsplit)
    x = jnp.asarray(rng.normal(0, 3, (6, 64)), jnp.float32)
    w = jnp.asarray(rng.normal(0, 1, (64,)), jnp.float32)
    a = np.asarray(rmsnorm(x, w, nsplit=nsplit))
    b = np.asarray(jnp_rmsnorm(x, w, nsplit=nsplit))
    np.testing.assert_array_equal(a, b)


@pytest.mark.parametrize("case", range(6))
def test_jnp_splitk_twin_random_sweep(case):
    from compile.kernels.splitk_matmul import jnp_splitk_matmul

    rng = np.random.default_rng(2000 + case)
    m = int(rng.integers(1, 48))
    k = int(rng.choice([64, 128, 256]))
    n = int(rng.integers(1, 64))
    nsplits = int(rng.choice([2, 4, 8]))
    x = jnp.asarray(rng.normal(0, 1, (m, k)), jnp.float32)
    w = jnp.asarray(rng.normal(0, 1, (k, n)), jnp.float32)
    a = np.asarray(splitk_matmul(x, w, nsplits=nsplits))
    b = np.asarray(jnp_splitk_matmul(x, w, nsplits=nsplits))
    np.testing.assert_array_equal(a, b)
