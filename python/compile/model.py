"""L2: the Llama-style decoder as a shape-specialized jax forward graph.

One function — `forward(cfg, g, t, strategy)` — covers all three phases of
the engine (paper §4.1, leveraging O3):

* decode        = forward(B, 1, fast(B))   one token per lane, B = batch bucket
* verify        = forward(G, T, invariant) fixed-shape grouped replay
* prefill chunk = forward(1, C, invariant) one request at a time

All graphs operate on a single flat f32 *state* array threaded through
executions with buffer donation (input_output_alias), so the multi-MB KV
pool never crosses the host boundary:

    state = [ K pool | V pool | logits region ]
              [L,S,Smax,kv]  [L,S,Smax,kv]  [R,V]

Lane `g` writes its token logits to rows `g*t .. g*t+t` of the logits
region; the rust engine reads them back with a tiny `extract` graph.

Position invariance (paper O2) holds by construction: every per-token
reduction (GEMM rows, per-token softmax, RMSNorm) has a fixed shape
independent of lane index, and lanes interact only through disjoint KV
slots. The rust integration tests assert this bitwise.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .config import ModelConfig, Strategy
from .kernels.rmsnorm import jnp_rmsnorm
from .kernels.splitk_matmul import matmul

# Weight tensors, in the exact order they are passed to the compiled graphs
# (and laid out in weights.bin). The rust runtime replays this order.
WEIGHT_SPEC = [
    ("embed", lambda c: (c.vocab, c.d_model)),
    ("wq", lambda c: (c.n_layers, c.d_model, c.q_dim)),
    ("wk", lambda c: (c.n_layers, c.d_model, c.kv_dim)),
    ("wv", lambda c: (c.n_layers, c.d_model, c.kv_dim)),
    ("wo", lambda c: (c.n_layers, c.q_dim, c.d_model)),
    ("attn_norm", lambda c: (c.n_layers, c.d_model)),
    ("ffn_norm", lambda c: (c.n_layers, c.d_model)),
    ("w_gate", lambda c: (c.n_layers, c.d_model, c.ffn_hidden)),
    ("w_up", lambda c: (c.n_layers, c.d_model, c.ffn_hidden)),
    ("w_down", lambda c: (c.n_layers, c.ffn_hidden, c.d_model)),
    ("final_norm", lambda c: (c.d_model,)),
    ("lm_head", lambda c: (c.d_model, c.vocab)),
]


def weight_shapes(cfg: ModelConfig):
    return [(name, shape_fn(cfg)) for name, shape_fn in WEIGHT_SPEC]


def init_weights(cfg: ModelConfig):
    """Synthetic weights, fixed seed (DESIGN.md §1: no real checkpoints)."""
    key = jax.random.PRNGKey(cfg.seed)
    out = []
    for name, shape in weight_shapes(cfg):
        key, sub = jax.random.split(key)
        if "norm" in name:
            w = jnp.ones(shape, jnp.float32)
        else:
            # scaled init keeps hidden-state magnitudes O(1) through depth
            fan_in = shape[-2] if len(shape) >= 2 else shape[-1]
            std = 1.0 / jnp.sqrt(jnp.float32(fan_in))
            w = jax.random.normal(sub, shape, jnp.float32) * std
        out.append((name, w))
    return out


def _rope(x, positions, theta):
    """x [T, H, hd] f32; positions [T] i32."""
    t, h, hd = x.shape
    half = hd // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions[:, None].astype(jnp.float32) * freqs[None, :]
    cos, sin = jnp.cos(ang)[:, None, :], jnp.sin(ang)[:, None, :]
    x1, x2 = x[..., :half], x[..., half:]
    return jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)


def _chunked_attention(q, k, v, mask, scale, ksplits):
    """FlashDecoding-style attention over the KV (sequence) dimension.

    q [T, H, hd]; k, v [Smax, KVH, hd]; mask [T, Smax] bool.

    The sequence axis is split into `ksplits` fixed chunks; each chunk
    yields an online-softmax partial (m, l, o) and partials are combined in
    a fixed sequential order. `ksplits` is the analogue of FA/FlashDecoding
    `num_splits`: different values change the reduction tree (paper §4.4
    sets num_splits=1 in the verification pass). For a given ksplits the
    computation is per-lane and fixed-shape, hence position-invariant.
    """
    t, h, hd = q.shape
    smax, kvh, _ = k.shape
    rep = h // kvh
    k = jnp.repeat(k, rep, axis=1)  # [Smax, H, hd]
    v = jnp.repeat(v, rep, axis=1)
    assert smax % ksplits == 0, (smax, ksplits)
    cs = smax // ksplits

    m = jnp.full((h, t), -1e30, jnp.float32)
    l = jnp.zeros((h, t), jnp.float32)
    o = jnp.zeros((h, t, hd), jnp.float32)
    for c in range(ksplits):
        kc = k[c * cs : (c + 1) * cs]
        vc = v[c * cs : (c + 1) * cs]
        mc_mask = mask[:, c * cs : (c + 1) * cs]
        s = jnp.einsum("thd,shd->hts", q, kc) * scale       # [H, T, cs]
        s = jnp.where(mc_mask[None, :, :], s, -1e9)
        m_c = jnp.max(s, axis=-1)                            # [H, T]
        p = jnp.exp(s - m_c[:, :, None])
        l_c = jnp.sum(p, axis=-1)
        o_c = jnp.einsum("hts,shd->htd", p, vc)
        m_new = jnp.maximum(m, m_c)
        a = jnp.exp(m - m_new)
        b = jnp.exp(m_c - m_new)
        l = l * a + l_c * b
        o = o * a[:, :, None] + o_c * b[:, :, None]
        m = m_new
    out = o / l[:, :, None]                                  # [H, T, hd]
    return jnp.moveaxis(out, 0, 1).reshape(t, h * hd)


def forward(
    cfg: ModelConfig,
    g: int,
    t: int,
    strategy: Strategy,
    state: jax.Array,
    tokens: jax.Array,     # [g*t] i32, lane-major
    slots: jax.Array,      # [g] i32
    start_pos: jax.Array,  # [g] i32 (first window position per lane)
    *weights: jax.Array,
) -> jax.Array:
    """One forward pass over `g` lanes x `t` tokens; returns updated state."""
    w = dict(zip([n for n, _ in WEIGHT_SPEC], weights))
    n = g * t
    mm = dict(
        kind=strategy.kind,
        seq_chunks=strategy.seq_chunks,
        partial_dtype=cfg.partial_dtype,
    )
    scale = 1.0 / jnp.sqrt(jnp.float32(cfg.head_dim))
    kvd = cfg.kv_dim

    # [g, t] absolute positions
    positions = start_pos[:, None] + jnp.arange(t, dtype=jnp.int32)[None, :]
    h = jnp.take(w["embed"], tokens, axis=0)  # [n, d]

    col = jnp.arange(cfg.max_seq, dtype=jnp.int32)

    for layer in range(cfg.n_layers):
        x = jnp_rmsnorm(
            h, w["attn_norm"][layer], nsplit=strategy.norm_splits,
            eps=cfg.rms_eps,
        )
        q = matmul(x, w["wq"][layer], nsplits=strategy.ffn_splits, **mm)
        k = matmul(x, w["wk"][layer], nsplits=strategy.ffn_splits, **mm)
        v = matmul(x, w["wv"][layer], nsplits=strategy.ffn_splits, **mm)

        # RoPE (per lane: positions differ)
        qg = q.reshape(g, t, cfg.n_heads, cfg.head_dim)
        kg = k.reshape(g, t, cfg.n_kv_heads, cfg.head_dim)
        q_lanes, k_lanes = [], []
        for lane in range(g):
            q_lanes.append(_rope(qg[lane], positions[lane], cfg.rope_theta))
            k_lanes.append(_rope(kg[lane], positions[lane], cfg.rope_theta))
        vg = v.reshape(g, t, kvd)

        # write K/V for the window: one contiguous DUS per lane per pool
        for lane in range(g):
            koff = cfg.kv_offset(0, layer, slots[lane], start_pos[lane])
            voff = cfg.kv_offset(1, layer, slots[lane], start_pos[lane])
            state = jax.lax.dynamic_update_slice(
                state, k_lanes[lane].reshape(t * kvd), (koff,)
            )
            state = jax.lax.dynamic_update_slice(
                state, vg[lane].reshape(t * kvd), (voff,)
            )

        # attention reads the (just-updated) pool row per lane
        attn_rows = []
        for lane in range(g):
            koff = cfg.kv_offset(0, layer, slots[lane], 0)
            voff = cfg.kv_offset(1, layer, slots[lane], 0)
            k_pool = jax.lax.dynamic_slice(
                state, (koff,), (cfg.max_seq * kvd,)
            ).reshape(cfg.max_seq, cfg.n_kv_heads, cfg.head_dim)
            v_pool = jax.lax.dynamic_slice(
                state, (voff,), (cfg.max_seq * kvd,)
            ).reshape(cfg.max_seq, cfg.n_kv_heads, cfg.head_dim)
            # query j attends to absolute positions <= start + j
            mask = col[None, :] <= positions[lane][:, None]  # [t, Smax]
            attn_rows.append(
                _chunked_attention(
                    q_lanes[lane], k_pool, v_pool, mask, scale,
                    strategy.attn_ksplits,
                )
            )
        attn = jnp.concatenate(attn_rows, axis=0)  # [n, q_dim]
        h = h + matmul(attn, w["wo"][layer], nsplits=strategy.ffn_splits, **mm)

        x = jnp_rmsnorm(
            h, w["ffn_norm"][layer], nsplit=strategy.norm_splits,
            eps=cfg.rms_eps,
        )
        gate = matmul(x, w["w_gate"][layer], nsplits=strategy.ffn_splits, **mm)
        up = matmul(x, w["w_up"][layer], nsplits=strategy.ffn_splits, **mm)
        f = jax.nn.silu(gate) * up
        # the FFN down-projection runs the actual pallas kernel in-graph
        h = h + matmul(
            f, w["w_down"][layer], nsplits=strategy.ffn_splits,
            impl="pallas", **mm,
        )

    x = jnp_rmsnorm(h, w["final_norm"], nsplit=strategy.norm_splits, eps=cfg.rms_eps)
    logits = matmul(x, w["lm_head"], nsplits=strategy.head_splits, **mm)
    logits = logits * jnp.float32(cfg.logit_scale)

    # publish [n, V] rows into the logits region
    state = jax.lax.dynamic_update_slice(
        state, logits.reshape(n * cfg.vocab), (cfg.logits_offset,)
    )
    return state


def extract_logits(cfg: ModelConfig, n: int, state: jax.Array) -> jax.Array:
    """Tiny companion graph: read the first n logits rows off the state."""
    flat = jax.lax.slice(
        state, (cfg.logits_offset,), (cfg.logits_offset + n * cfg.vocab,)
    )
    return flat.reshape(n, cfg.vocab)


def forward_ref(cfg, g, t, state, tokens, slots, start_pos, weights):
    """Oracle: same semantics via ref.py primitives (plain f32 schedules)."""
    from .kernels import ref

    w = dict(zip([nm for nm, _ in WEIGHT_SPEC], [jnp.asarray(x) for x in weights]))
    state = jnp.asarray(state)
    kvd = cfg.kv_dim
    positions = jnp.asarray(start_pos)[:, None] + jnp.arange(t, dtype=jnp.int32)
    h = jnp.take(w["embed"], jnp.asarray(tokens), axis=0)
    col = jnp.arange(cfg.max_seq, dtype=jnp.int32)
    scale = 1.0 / float(jnp.sqrt(jnp.float32(cfg.head_dim)))

    for layer in range(cfg.n_layers):
        x = ref.rmsnorm_ref(h, w["attn_norm"][layer], eps=cfg.rms_eps)
        q = ref.matmul_ref(x, w["wq"][layer])
        k = ref.matmul_ref(x, w["wk"][layer])
        v = ref.matmul_ref(x, w["wv"][layer])
        qg = q.reshape(g, t, cfg.n_heads, cfg.head_dim)
        kg = k.reshape(g, t, cfg.n_kv_heads, cfg.head_dim)
        vg = v.reshape(g, t, kvd)
        for lane in range(g):
            kr = ref.rope_ref(kg[lane], positions[lane], cfg.rope_theta)
            koff = cfg.kv_offset(0, layer, int(slots[lane]), int(start_pos[lane]))
            voff = cfg.kv_offset(1, layer, int(slots[lane]), int(start_pos[lane]))
            state = jax.lax.dynamic_update_slice(
                state, kr.reshape(t * kvd), (koff,)
            )
            state = jax.lax.dynamic_update_slice(
                state, vg[lane].reshape(t * kvd), (voff,)
            )
        attn_rows = []
        for lane in range(g):
            koff = cfg.kv_offset(0, layer, int(slots[lane]), 0)
            voff = cfg.kv_offset(1, layer, int(slots[lane]), 0)
            k_pool = jax.lax.dynamic_slice(
                state, (koff,), (cfg.max_seq * kvd,)
            ).reshape(cfg.max_seq, cfg.n_kv_heads, cfg.head_dim)
            v_pool = jax.lax.dynamic_slice(
                state, (voff,), (cfg.max_seq * kvd,)
            ).reshape(cfg.max_seq, cfg.n_kv_heads, cfg.head_dim)
            rep = cfg.n_heads // cfg.n_kv_heads
            mask = col[None, :] <= positions[lane][:, None]
            qr = ref.rope_ref(qg[lane], positions[lane], cfg.rope_theta)
            out = ref.attention_ref(
                qr,
                jnp.repeat(k_pool, rep, axis=1),
                jnp.repeat(v_pool, rep, axis=1),
                mask,
                scale,
            )
            attn_rows.append(out.reshape(t, cfg.q_dim))
        attn = jnp.concatenate(attn_rows, axis=0)
        h = h + ref.matmul_ref(attn, w["wo"][layer])
        x = ref.rmsnorm_ref(h, w["ffn_norm"][layer], eps=cfg.rms_eps)
        h = h + ref.swiglu_ref(
            x, w["w_gate"][layer], w["w_up"][layer], w["w_down"][layer]
        )

    x = ref.rmsnorm_ref(h, w["final_norm"], eps=cfg.rms_eps)
    logits = ref.matmul_ref(x, w["lm_head"]) * cfg.logit_scale
    state = jax.lax.dynamic_update_slice(
        state, logits.reshape(g * t * cfg.vocab), (cfg.logits_offset,)
    )
    return state
