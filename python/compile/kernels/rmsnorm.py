"""L1: RMSNorm as a Pallas kernel with a configurable split reduction.

RMSNorm reduces over the feature dimension per token. GPU kernels split
that reduction across warps for occupancy; the split count changes the
accumulation tree (paper Table 2: RMSNorm is position-invariant at
num_splits=1 but not batch-invariant in general). We reproduce both
schedules: `nsplit=1` is the universal (invariant) schedule, `nsplit>1`
computes per-chunk partial sums of squares combined by the same fixed
pairwise tree as the split-K GEMM.

The whole row block lives in VMEM (rows x d_model tiles are tiny relative
to the 16 MB budget — DESIGN.md §8); grid is 1, matching a single-CTA
per-token normalization.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .splitk_matmul import combine_tree


def _rmsnorm_kernel(x_ref, w_ref, o_ref, *, nsplit, eps):
    x = x_ref[...]  # [M, D] f32
    m, d = x.shape
    if nsplit == 1:
        ss = jnp.sum(x * x, axis=-1)  # [M]
    else:
        parts = x.reshape(m, nsplit, d // nsplit)
        partial = jnp.sum(parts * parts, axis=-1)        # [M, nsplit]
        ss = combine_tree(jnp.moveaxis(partial, 1, 0))   # fixed tree -> [M]
    inv = jax.lax.rsqrt(ss / d + eps)
    o_ref[...] = x * inv[:, None] * w_ref[...][None, :]


@functools.partial(jax.jit, static_argnames=("nsplit", "eps"))
def rmsnorm(
    x: jax.Array, w: jax.Array, *, nsplit: int = 1, eps: float = 1e-5
) -> jax.Array:
    """f32 [M, D] RMSNorm with an `nsplit`-way feature-dim reduction."""
    m, d = x.shape
    assert d % nsplit == 0, (d, nsplit)
    kernel = functools.partial(_rmsnorm_kernel, nsplit=nsplit, eps=eps)
    return pl.pallas_call(
        kernel,
        out_shape=jax.ShapeDtypeStruct((m, d), jnp.float32),
        interpret=True,
    )(x, w)


@functools.partial(jax.jit, static_argnames=("nsplit", "eps"))
def jnp_rmsnorm(
    x: jax.Array, w: jax.Array, *, nsplit: int = 1, eps: float = 1e-5
) -> jax.Array:
    """XLA-native form of the same schedule (bitwise-identical to `rmsnorm`,
    asserted in pytest); used inside the serving graphs to avoid the pallas
    interpret-mode per-call overhead on CPU-PJRT."""
    m, d = x.shape
    assert d % nsplit == 0, (d, nsplit)
    if nsplit == 1:
        ss = jnp.sum(x * x, axis=-1)
    else:
        parts = x.reshape(m, nsplit, d // nsplit)
        partial = jnp.sum(parts * parts, axis=-1)
        ss = combine_tree(jnp.moveaxis(partial, 1, 0))
    inv = jax.lax.rsqrt(ss / d + eps)
    return x * inv[:, None] * w[None, :]
