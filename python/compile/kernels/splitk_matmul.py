"""L1: split-K matmul as a Pallas kernel.

This kernel *is* the paper's non-determinism mechanism, transplanted to the
pallas programming model. A GPU split-K GEMM partitions the reduction (K)
dimension across thread blocks and combines partial results in a second
step; how many splits are chosen depends on the input shape, so the
floating-point reduction tree — and therefore the low-order bits of the
result — change with the batch bucket (paper §2.2, Fig. 3).

Hardware adaptation (DESIGN.md §6): instead of threadblocks we use the
pallas grid over K-blocks, with each partial product produced from a
VMEM-resident tile pair (`BlockSpec` over the K axis plays the role of the
threadblock split). Partials are rounded to `partial_dtype` before the
cross-split combine — mirroring partial-result stores on real hardware and
making the drift measurable at f32. The combine is an explicit fixed-shape
pairwise tree, so for a *given* `nsplits` the kernel is position-invariant
(paper O2): the result for a row does not depend on other rows' values or
on the row's position in the batch.

`nsplits=1` degenerates to a single full-K product — the universal schedule
used by the invariant strategy. Kernels are lowered with `interpret=True`
(CPU-PJRT cannot execute Mosaic custom-calls); real-TPU efficiency is
estimated structurally in DESIGN.md §8.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def combine_tree(parts: jax.Array) -> jax.Array:
    """Fixed pairwise reduction tree over axis 0 (length must be a power of 2).

    The tree's *shape* is a compile-time function of `parts.shape[0]`; two
    different split counts therefore produce different accumulation orders,
    which is exactly the effect split-K has on GPU GEMMs.
    """
    n = parts.shape[0]
    assert n & (n - 1) == 0, f"combine_tree needs a power-of-2 count, got {n}"
    while n > 1:
        parts = parts[0 : n // 2] + parts[n // 2 : n]
        n //= 2
    return parts[0]


def _splitk_kernel(x_ref, w_ref, o_ref, *, partial_dtype):
    """One grid step: a full [M, K/nsplits] x [K/nsplits, N] tile product.

    The f32 MXU-style accumulation happens inside the tile; the *stored*
    partial is rounded to `partial_dtype`, as real kernels round partial
    results when staging them through memory.
    """
    acc = jnp.dot(
        x_ref[...], w_ref[...], preferred_element_type=jnp.float32
    )
    o_ref[0, :, :] = acc.astype(partial_dtype).astype(jnp.float32)


@functools.partial(jax.jit, static_argnames=("nsplits", "partial_dtype"))
def splitk_matmul(
    x: jax.Array,
    w: jax.Array,
    *,
    nsplits: int = 1,
    partial_dtype: str = "bfloat16",
) -> jax.Array:
    """f32 [M, K] @ [K, N] -> [M, N] with an `nsplits`-way split-K schedule.

    nsplits == 1 reproduces a plain single-pass product (no partial
    rounding): the batch-invariant universal schedule.
    """
    m, k = x.shape
    k2, n = w.shape
    assert k == k2, (x.shape, w.shape)
    assert k % nsplits == 0, f"K={k} not divisible by nsplits={nsplits}"
    if nsplits == 1:
        return _full_matmul_pallas(x, w)
    pdt = jnp.dtype(partial_dtype)
    kernel = functools.partial(_splitk_kernel, partial_dtype=pdt)
    partials = pl.pallas_call(
        kernel,
        grid=(nsplits,),
        in_specs=[
            pl.BlockSpec((m, k // nsplits), lambda s: (0, s)),
            pl.BlockSpec((k // nsplits, n), lambda s: (s, 0)),
        ],
        out_specs=pl.BlockSpec((1, m, n), lambda s: (s, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((nsplits, m, n), jnp.float32),
        interpret=True,
    )(x, w)
    return combine_tree(partials)


def _full_kernel(x_ref, w_ref, o_ref):
    o_ref[...] = jnp.dot(
        x_ref[...], w_ref[...], preferred_element_type=jnp.float32
    )


def _full_matmul_pallas(x: jax.Array, w: jax.Array) -> jax.Array:
    m, k = x.shape
    _, n = w.shape
    return pl.pallas_call(
        _full_kernel,
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=True,
    )(x, w)


@functools.partial(jax.jit, static_argnames=("nsplits", "partial_dtype"))
def jnp_splitk_matmul(
    x: jax.Array,
    w: jax.Array,
    *,
    nsplits: int = 1,
    partial_dtype: str = "bfloat16",
) -> jax.Array:
    """XLA-native lowering of the split-K schedule.

    Bitwise-identical to `splitk_matmul` (asserted in pytest): the same
    per-split f32 tile products, the same `partial_dtype` rounding, the
    same fixed combine tree — expressed as a reshaped einsum instead of a
    pallas grid. The serving graphs use this form for most GEMMs because
    pallas `interpret=True` adds per-call emulation overhead on CPU-PJRT
    (~0.4 ms/call; see EXPERIMENTS.md §Perf), while the pallas kernel
    remains the ground truth and stays on the real path for the FFN
    down-projection.
    """
    m, k = x.shape
    k2, n = w.shape
    assert k == k2 and k % nsplits == 0, (x.shape, w.shape, nsplits)
    if nsplits == 1:
        return jnp.dot(x, w, preferred_element_type=jnp.float32)
    pdt = jnp.dtype(partial_dtype)
    xs = x.reshape(m, nsplits, k // nsplits)
    ws = w.reshape(nsplits, k // nsplits, n)
    parts = jnp.einsum(
        "msk,skn->smn", xs, ws, preferred_element_type=jnp.float32
    )
    parts = parts.astype(pdt).astype(jnp.float32)
    return combine_tree(parts)


def seqchunk_matmul(x: jax.Array, w: jax.Array, *, chunks: int = 8) -> jax.Array:
    """Batch-invariant GEMM: a *sequential* fixed-chunk K accumulation.

    This is the universal reduction schedule of batch-invariant computation
    (He et al.): every token's dot product is accumulated left-to-right over
    the same fixed K-chunks regardless of batch shape. The serial carry
    chain is what real batch-invariant kernels pay for — XLA cannot
    tree-reduce across `scan` steps, mirroring the forfeited split-K
    parallelism the paper measures in Fig. 4a.
    """
    m, k = x.shape
    k2, n = w.shape
    assert k == k2 and k % chunks == 0, (x.shape, w.shape, chunks)
    xc = x.reshape(m, chunks, k // chunks).transpose(1, 0, 2)
    wc = w.reshape(chunks, k // chunks, n)

    def body(acc, xw):
        xi, wi = xw
        return acc + jnp.dot(xi, wi, preferred_element_type=jnp.float32), None

    acc0 = jnp.zeros((m, n), jnp.float32)
    acc, _ = jax.lax.scan(body, acc0, (xc, wc))
    return acc


def matmul(
    x: jax.Array,
    w: jax.Array,
    *,
    kind: str,
    nsplits: int = 1,
    seq_chunks: int = 8,
    partial_dtype: str = "bfloat16",
    impl: str = "jnp",
) -> jax.Array:
    """Strategy-dispatched GEMM used by the L2 model.

    `impl` selects the lowering for the fast path: "pallas" (the L1 kernel
    itself) or "jnp" (its bitwise-identical XLA-native form).
    """
    if kind == "fast":
        f = splitk_matmul if impl == "pallas" else jnp_splitk_matmul
        return f(x, w, nsplits=nsplits, partial_dtype=partial_dtype)
    if kind == "inv":
        return seqchunk_matmul(x, w, chunks=seq_chunks)
    raise ValueError(f"unknown GEMM strategy kind: {kind}")
