"""Pure-jnp oracles for the L1 pallas kernels and the L2 model blocks.

These are the mathematical references: plain f32 computations with XLA's
default schedules. Kernel tests assert `allclose` against these within the
tolerance implied by the partial dtype, plus *exact* structural properties
(position invariance, split-count divergence) that the system relies on.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def matmul_ref(x: jax.Array, w: jax.Array) -> jax.Array:
    return jnp.dot(
        x.astype(jnp.float32),
        w.astype(jnp.float32),
        preferred_element_type=jnp.float32,
    )


def rmsnorm_ref(x: jax.Array, w: jax.Array, eps: float = 1e-5) -> jax.Array:
    ss = jnp.mean(x * x, axis=-1, keepdims=True)
    return x * jax.lax.rsqrt(ss + eps) * w[None, :]


def swiglu_ref(x, w_gate, w_up, w_down):
    g = matmul_ref(x, w_gate)
    u = matmul_ref(x, w_up)
    return matmul_ref(jax.nn.silu(g) * u, w_down)


def attention_ref(q, k, v, mask, scale):
    """q [T, H, hd]; k, v [Smax, H, hd]; mask [T, Smax] bool (True = attend)."""
    scores = jnp.einsum("thd,shd->hts", q, k) * scale
    scores = jnp.where(mask[None, :, :], scores, -1e9)
    p = jax.nn.softmax(scores, axis=-1)
    return jnp.einsum("hts,shd->thd", p, v)


def rope_ref(x, positions, theta: float = 10000.0):
    """x [T, H, hd]; positions [T] i32. Rotates pairs (even, odd)."""
    t, h, hd = x.shape
    half = hd // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions[:, None].astype(jnp.float32) * freqs[None, :]  # [T, half]
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = x[..., :half], x[..., half:]
    cos, sin = cos[:, None, :], sin[:, None, :]
    return jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
