"""Model configuration shared by the L2 graph builder and the AOT pipeline.

The rust engine never imports this; it reads the JSON manifest emitted by
`aot.py`. Keep every field JSON-serializable.
"""

from __future__ import annotations

import dataclasses
import json
from dataclasses import dataclass


@dataclass(frozen=True)
class ModelConfig:
    """A Llama-style decoder-only transformer, sized for CPU-PJRT serving.

    `slots` includes one reserved *trash* slot (index `slots - 1`) used by
    padding lanes in grouped verification; the engine only allocates user
    requests to slots `0 .. slots - 2`.
    """

    name: str = "tiny"
    vocab: int = 2048
    d_model: int = 256
    n_layers: int = 4
    n_heads: int = 8
    n_kv_heads: int = 4
    head_dim: int = 32
    ffn_hidden: int = 704
    max_seq: int = 640          # Smax: per-slot KV capacity (tokens)
    slots: int = 17             # S: concurrent sequences + 1 trash slot
    max_fwd_tokens: int = 512   # R: logits region rows = max G*T per forward
    rope_theta: float = 10000.0
    rms_eps: float = 1e-5
    logit_scale: float = 6.0    # sharpens/flattens logits; calibrates flip rate
    partial_dtype: str = "bfloat16"  # cross-split partial storage (drift source)
    seed: int = 42

    # ---- derived sizes (floats) ------------------------------------------
    @property
    def kv_dim(self) -> int:
        return self.n_kv_heads * self.head_dim

    @property
    def q_dim(self) -> int:
        return self.n_heads * self.head_dim

    @property
    def pool_floats(self) -> int:
        """K pool + V pool, layout [L, S, Smax, kv_dim] each."""
        return 2 * self.n_layers * self.slots * self.max_seq * self.kv_dim

    @property
    def logits_floats(self) -> int:
        return self.max_fwd_tokens * self.vocab

    @property
    def state_floats(self) -> int:
        return self.pool_floats + self.logits_floats

    def kv_offset(self, which: int, layer_like, slot_like, pos_like):
        """Flat-state float offset of pool[which][layer][slot][pos][0].

        Works with python ints or traced jax scalars. `which`: 0 = K, 1 = V.
        """
        per_pool = self.n_layers * self.slots * self.max_seq * self.kv_dim
        per_layer = self.slots * self.max_seq * self.kv_dim
        per_slot = self.max_seq * self.kv_dim
        return (
            which * per_pool
            + layer_like * per_layer
            + slot_like * per_slot
            + pos_like * self.kv_dim
        )

    @property
    def logits_offset(self) -> int:
        return self.pool_floats

    def n_params(self) -> int:
        d, f, v = self.d_model, self.ffn_hidden, self.vocab
        attn = d * self.q_dim + 2 * d * self.kv_dim + self.q_dim * d
        ffn = 3 * d * f
        per_layer = attn + ffn + 2 * d
        return self.n_layers * per_layer + 2 * v * d + d

    def to_json(self) -> dict:
        return dataclasses.asdict(self)


PRESETS = {
    "tiny": ModelConfig(),
    # ~26M params; for the larger end-to-end validation run.
    "small": ModelConfig(
        name="small",
        vocab=4096,
        d_model=512,
        n_layers=8,
        n_heads=8,
        n_kv_heads=4,
        head_dim=64,
        ffn_hidden=1376,
        max_seq=640,
        slots=17,
    ),
    # minimal config for fast unit tests
    "test": ModelConfig(
        name="test",
        vocab=256,
        d_model=64,
        n_layers=2,
        n_heads=4,
        n_kv_heads=2,
        head_dim=16,
        ffn_hidden=128,
        max_seq=96,
        slots=5,
        max_fwd_tokens=64,
    ),
}


# Fast-path reduction-strategy heuristics, keyed by decode batch bucket.
# Mirrors real GPU kernels: more split-K parallelism at low batch sizes
# (split-K / FlashDecoding-style KV splits), none at high batch sizes.
FFN_SPLITS_BY_BUCKET = {1: 8, 2: 8, 4: 4, 8: 2, 16: 1, 32: 1}
HEAD_SPLITS_BY_BUCKET = {1: 8, 2: 8, 4: 4, 8: 2, 16: 1, 32: 1}
ATTN_KSPLITS_BY_BUCKET = {1: 4, 2: 4, 4: 2, 8: 2, 16: 1, 32: 1}
NORM_SPLITS_BY_BUCKET = {1: 4, 2: 4, 4: 2, 8: 2, 16: 1, 32: 1}


@dataclass(frozen=True)
class Strategy:
    """A reduction schedule for one compiled forward graph.

    `fast(bucket)` mimics shape-tuned GPU kernels: the reduction tree varies
    with the batch bucket and cross-split partials are rounded to
    `ModelConfig.partial_dtype` (the floating-point drift source).

    `invariant()` is the single universal schedule (split-K = 1, sequential
    K-chunk accumulation, attention num_splits = 1) used by the verifier,
    prefill, and the SGLang-Deterministic-analogue batch-invariant mode.
    """

    kind: str            # "fast" | "inv"
    ffn_splits: int = 1
    head_splits: int = 1
    attn_ksplits: int = 1
    norm_splits: int = 1
    seq_chunks: int = 8  # invariant mode: sequential K chunks in GEMMs

    @staticmethod
    def fast(bucket: int) -> "Strategy":
        return Strategy(
            kind="fast",
            ffn_splits=FFN_SPLITS_BY_BUCKET[bucket],
            head_splits=HEAD_SPLITS_BY_BUCKET[bucket],
            attn_ksplits=ATTN_KSPLITS_BY_BUCKET[bucket],
            norm_splits=NORM_SPLITS_BY_BUCKET[bucket],
        )

    @staticmethod
    def invariant() -> "Strategy":
        return Strategy(kind="inv")

    @property
    def tag(self) -> str:
        if self.kind == "inv":
            return "inv"
        return (
            f"fast_f{self.ffn_splits}h{self.head_splits}"
            f"a{self.attn_ksplits}n{self.norm_splits}"
        )


def config_from_json(d: dict) -> ModelConfig:
    fields = {f.name for f in dataclasses.fields(ModelConfig)}
    return ModelConfig(**{k: v for k, v in d.items() if k in fields})


def load_config(path: str) -> ModelConfig:
    with open(path) as f:
        return config_from_json(json.load(f))
