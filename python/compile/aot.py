"""AOT pipeline: lower every forward-graph variant to HLO text + manifest.

Python runs exactly once (`make artifacts`); the rust engine is then
self-contained. Interchange format is HLO *text*, not serialized
HloModuleProto — jax >= 0.5 emits 64-bit instruction ids that the xla
crate's xla_extension 0.5.1 rejects, while the text parser reassigns ids
(see /opt/xla-example/README.md).

Outputs (in --out-dir, default ../artifacts):
  model_config.json   ModelConfig as JSON
  weights.bin         f32 little-endian tensors, manifest order
  manifest.json       state layout, weight table, artifact table
  *.hlo.txt           one per (shape, strategy) graph variant

Artifact sets:
  default   decode buckets (fast + invariant), prefill/verify windows,
            logits extracts — everything the engine needs at runtime
  micro     standalone GEMM / RMSNorm graphs for the Fig. 4 harness
  ablation  the wider window/group grid for Fig. 9 / Fig. 12
"""

from __future__ import annotations

import argparse
import functools
import hashlib
import json
import os
import sys
import time

import numpy as np

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from .config import PRESETS, ModelConfig, Strategy
from .kernels.rmsnorm import rmsnorm
from .kernels.splitk_matmul import matmul
from .model import extract_logits, forward, init_weights, weight_shapes


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=False
    )
    return comp.as_hlo_text()


def decode_buckets(cfg: ModelConfig) -> list[int]:
    """Powers of two up to the number of usable slots (capped at 32)."""
    out, b = [], 1
    while b <= min(32, cfg.slots - 1):
        out.append(b)
        b *= 2
    return out


def prefill_chunks(cfg: ModelConfig) -> list[int]:
    out, c = [], 16
    while c <= min(256, cfg.max_fwd_tokens):
        out.append(c)
        c *= 2
    return out


def default_windows(cfg: ModelConfig) -> list[tuple[int, int]]:
    """(group, window) verify shapes emitted by default."""
    shapes = [(1, t) for t in prefill_chunks(cfg)]
    for g in (2, 4, 8):
        for t in (16, 32, 64):
            if g * t <= cfg.max_fwd_tokens and g <= cfg.slots - 1:
                shapes.append((g, t))
    return shapes


def ablation_windows(cfg: ModelConfig) -> list[tuple[int, int]]:
    shapes = []
    for g in (1, 2, 4, 8, 16):
        for t in (16, 32, 64, 128, 256, 512):
            if g * t <= cfg.max_fwd_tokens and g <= cfg.slots - 1:
                shapes.append((g, t))
    return shapes


def extract_sizes(cfg: ModelConfig) -> list[int]:
    out, n = [], 1
    while n <= cfg.max_fwd_tokens:
        out.append(n)
        n *= 2
    return out


class Emitter:
    def __init__(self, cfg: ModelConfig, out_dir: str):
        self.cfg = cfg
        self.out_dir = out_dir
        self.artifacts: list[dict] = []

    def emit(self, name: str, lowered, *, kind: str, meta: dict, donates: bool):
        t0 = time.time()
        text = to_hlo_text(lowered)
        fname = f"{name}.hlo.txt"
        with open(os.path.join(self.out_dir, fname), "w") as f:
            f.write(text)
        if donates and "alias" not in text[:2000]:
            raise RuntimeError(f"{name}: expected input_output_alias, none found")
        self.artifacts.append(
            {"name": name, "file": fname, "kind": kind, "donates_state": donates, **meta}
        )
        print(
            f"  {name}: {len(text) / 1e6:.2f} MB hlo, "
            f"{time.time() - t0:.1f}s",
            flush=True,
        )

    def fwd_shapes(self, g: int, t: int):
        cfg = self.cfg
        return (
            jax.ShapeDtypeStruct((cfg.state_floats,), jnp.float32),
            jax.ShapeDtypeStruct((g * t,), jnp.int32),
            jax.ShapeDtypeStruct((g,), jnp.int32),
            jax.ShapeDtypeStruct((g,), jnp.int32),
            *[
                jax.ShapeDtypeStruct(shape, jnp.float32)
                for _, shape in weight_shapes(cfg)
            ],
        )

    def emit_forward(self, name: str, g: int, t: int, strategy: Strategy, kind: str):
        fn = functools.partial(forward, self.cfg, g, t, strategy)
        lowered = jax.jit(fn, donate_argnums=(0,)).lower(*self.fwd_shapes(g, t))
        self.emit(
            name,
            lowered,
            kind=kind,
            donates=True,
            meta={"g": g, "t": t, "strategy": strategy.kind, "tag": strategy.tag},
        )

    def emit_extract(self, n: int):
        cfg = self.cfg
        fn = functools.partial(extract_logits, cfg, n)
        lowered = jax.jit(fn).lower(
            jax.ShapeDtypeStruct((cfg.state_floats,), jnp.float32)
        )
        self.emit(
            f"extract_r{n}",
            lowered,
            kind="extract",
            donates=False,
            meta={"g": n, "t": 1, "strategy": "none", "tag": "extract"},
        )


def emit_default(em: Emitter):
    cfg = em.cfg
    for b in decode_buckets(cfg):
        em.emit_forward(f"decode_fast_b{b}", b, 1, Strategy.fast(b), "decode")
        em.emit_forward(f"decode_inv_b{b}", b, 1, Strategy.invariant(), "decode")
    for g, t in default_windows(cfg):
        em.emit_forward(f"window_inv_g{g}_t{t}", g, t, Strategy.invariant(), "window")
    for n in extract_sizes(cfg):
        em.emit_extract(n)


def emit_ablation(em: Emitter):
    done = {(a["g"], a["t"]) for a in em.artifacts if a["kind"] == "window"}
    for g, t in ablation_windows(em.cfg):
        if (g, t) not in done:
            em.emit_forward(
                f"window_inv_g{g}_t{t}", g, t, Strategy.invariant(), "window"
            )


def emit_micro(em: Emitter):
    """Standalone kernel graphs for the Fig. 4 analogue (fast vs invariant)."""
    cfg = em.cfg
    k, n = cfg.ffn_hidden, cfg.d_model  # down-projection shape, as in Fig. 4a
    # shape-tuned split heuristic, like the model's decode buckets: more
    # split-K parallelism at low token counts (this is what makes the fast
    # GEMM batch-*variant*, Table 2)
    splits_for = lambda m: {1: 8, 2: 8, 4: 4, 8: 4, 16: 2, 32: 2}.get(m, 1)
    for m in (1, 2, 4, 8, 16, 32, 64, 128, 256, 512):
        xs = jax.ShapeDtypeStruct((m, k), jnp.float32)
        ws = jax.ShapeDtypeStruct((k, n), jnp.float32)

        def gemm_fast(x, w, s=splits_for(m)):
            return matmul(
                x, w, kind="fast", nsplits=s, partial_dtype=cfg.partial_dtype
            )

        def gemm_inv(x, w):
            return matmul(x, w, kind="inv", seq_chunks=8)

        em.emit(
            f"gemm_fast_m{m}",
            jax.jit(gemm_fast).lower(xs, ws),
            kind="micro_gemm",
            donates=False,
            meta={"g": m, "t": 0, "strategy": "fast", "tag": "micro"},
        )
        em.emit(
            f"gemm_inv_m{m}",
            jax.jit(gemm_inv).lower(xs, ws),
            kind="micro_gemm",
            donates=False,
            meta={"g": m, "t": 0, "strategy": "inv", "tag": "micro"},
        )

        xs2 = jax.ShapeDtypeStruct((m, cfg.d_model), jnp.float32)
        ws2 = jax.ShapeDtypeStruct((cfg.d_model,), jnp.float32)
        em.emit(
            f"rmsnorm_fast_m{m}",
            jax.jit(lambda x, w: rmsnorm(x, w, nsplit=4)).lower(xs2, ws2),
            kind="micro_norm",
            donates=False,
            meta={"g": m, "t": 0, "strategy": "fast", "tag": "micro"},
        )
        em.emit(
            f"rmsnorm_inv_m{m}",
            jax.jit(lambda x, w: rmsnorm(x, w, nsplit=1)).lower(xs2, ws2),
            kind="micro_norm",
            donates=False,
            meta={"g": m, "t": 0, "strategy": "inv", "tag": "micro"},
        )


def write_weights(cfg: ModelConfig, out_dir: str) -> list[dict]:
    table, offset = [], 0
    with open(os.path.join(out_dir, "weights.bin"), "wb") as f:
        for name, w in init_weights(cfg):
            arr = np.asarray(w, dtype=np.float32)
            arr.tofile(f)
            table.append(
                {
                    "name": name,
                    "shape": list(arr.shape),
                    "offset_floats": offset,
                    "size_floats": int(arr.size),
                }
            )
            offset += int(arr.size)
    return table


def source_stamp(cfg: ModelConfig, sets: list[str]) -> str:
    h = hashlib.sha256()
    h.update(json.dumps(cfg.to_json(), sort_keys=True).encode())
    h.update(",".join(sorted(sets)).encode())
    base = os.path.dirname(__file__)
    for fn in ("model.py", "aot.py", "config.py",
               "kernels/splitk_matmul.py", "kernels/rmsnorm.py"):
        with open(os.path.join(base, fn), "rb") as f:
            h.update(f.read())
    return h.hexdigest()


def main() -> int:
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--out-dir", default=os.path.join(os.path.dirname(__file__), "..", "..", "artifacts"))
    p.add_argument("--preset", default=os.environ.get("LLM42_PRESET", "tiny"),
                   choices=sorted(PRESETS))
    p.add_argument("--sets", default="default",
                   help="comma list of: default,micro,ablation")
    p.add_argument("--force", action="store_true")
    args = p.parse_args()

    cfg = PRESETS[args.preset]
    sets = [s for s in args.sets.split(",") if s]
    out_dir = os.path.abspath(args.out_dir)
    os.makedirs(out_dir, exist_ok=True)

    stamp = source_stamp(cfg, sets)
    stamp_path = os.path.join(out_dir, ".stamp")
    if not args.force and os.path.exists(stamp_path):
        if open(stamp_path).read().strip() == stamp:
            print(f"artifacts up to date in {out_dir} (stamp match)")
            return 0

    t0 = time.time()
    print(f"emitting artifacts for preset={args.preset} sets={sets} -> {out_dir}")
    em = Emitter(cfg, out_dir)
    if "default" in sets:
        emit_default(em)
    if "ablation" in sets:
        emit_ablation(em)
    if "micro" in sets:
        emit_micro(em)

    weights_table = write_weights(cfg, out_dir)
    with open(os.path.join(out_dir, "model_config.json"), "w") as f:
        json.dump(cfg.to_json(), f, indent=2)
    manifest = {
        "model": cfg.to_json(),
        "state": {
            "total_floats": cfg.state_floats,
            "pool_floats": cfg.pool_floats,
            "logits_offset": cfg.logits_offset,
            "logits_rows": cfg.max_fwd_tokens,
            "vocab": cfg.vocab,
        },
        "weight_order": [nm for nm, _ in weight_shapes(cfg)],
        "weights": weights_table,
        "artifacts": em.artifacts,
    }
    with open(os.path.join(out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2)
    with open(stamp_path, "w") as f:
        f.write(stamp)
    print(f"done: {len(em.artifacts)} artifacts in {time.time() - t0:.0f}s")
    return 0


if __name__ == "__main__":
    sys.exit(main())
